//! Forward pass — paper Algorithm 2 (width-blocked BRGEMM).
//!
//! For every output block of 64 columns, build the tap offset lists
//! (`A_ptrs[s] = &Weight[s,0,0]`, `B_ptrs[s] = &In[0, pos + s·d]`) and run
//! one BRGEMM with `l_br = S`:
//!
//! ```text
//! for pos = 0 .. Q step 64:              # cache blocking (width)
//!     for s = 0 .. S:                    # pointer generation
//!         A_ptrs[s] = Weight[s, :, :]    # (K, C) tap, contiguous in SKC
//!         B_ptrs[s] = In[:, pos + s·d]   # (C, 64) strided panel
//!     Out[:, pos .. pos+64] = BRGEMM(A_ptrs, B_ptrs, S)
//! ```
//!
//! GEMM shape per block: `m = K`, `n = 64`, `k = C` — so the paper's
//! LIBXSMM-friendliness condition is `√(C·K) ≤ 64` (Sec. 3.1).
//!
//! Batched entry points take an [`ExecCtx`]: worker count, batch-vs-grid
//! work [`Partition`] (grid splits the `N × ceil(Q/64)` width-block grid,
//! so a single long image parallelises), and the resolved SIMD
//! micro-kernel set the BRGEMM blocks dispatch to.

use super::bf16::{narrow_row_into, Bf16};
use super::brgemm::{brgemm_bf16_with, brgemm_f32_with, brgemm_i8_with};
use super::params::{ConvParams, WIDTH_BLOCK};
use super::post::{apply_block, apply_block_staged, PostOps};
use super::simd::{self, MicroKernelSet};
use super::threading::{
    par_batch_chunks_scratch, par_grid_chunks_scratch, ExecCtx, GridStripe, Partition,
};

/// Tap offsets of the `(S, K, C)` forward weight: `a_offs[s] = s·K·C`.
/// Block-position independent, so a plan computes them exactly once
/// (the paper regenerates per block; hoisting is equivalent and cheaper —
/// see EXPERIMENTS.md §Perf).
pub fn forward_a_offs(p: &ConvParams) -> Vec<usize> {
    (0..p.s).map(|is| is * p.k * p.c).collect()
}

/// One `(K, nb)` output block at column `pos` of one image: generate the
/// tap offsets, run the BRGEMM, fuse the post-op epilogue. The unit of
/// work of both partitionings — batch workers loop it over a whole image,
/// grid workers get handed individual `(image, block)` cells.
#[allow(clippy::too_many_arguments)]
#[inline]
fn forward_block(
    uks: &MicroKernelSet,
    p: &ConvParams,
    x: &[f32],
    w_skc: &[f32],
    out_row: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d; // &In[0, pos + s*d], row stride = w
    }
    brgemm_f32_with(
        uks,
        w_skc,
        a_offs,
        c,
        x,
        b_offs,
        w,
        &mut out_row[pos..],
        q,
        k,
        nb,
        c,
        true,
    );
    apply_block(ops, bias, res_row, out_row, k, q, pos, nb);
}

/// [`forward_block`] for a grid worker: the BRGEMM computes into the
/// worker's private contiguous `(K, nb)` staging block (`ldc = nb` —
/// `ldc` only moves stores, never the FMA order, so grid stays bit-exact
/// vs batch), the epilogue runs on the hot staging block, and only the
/// worker's own column stripe of the shared output row is stored through
/// the [`GridStripe`] handle — no aliasing `&mut` over the output, ever.
#[allow(clippy::too_many_arguments)]
#[inline]
fn forward_block_grid(
    uks: &MicroKernelSet,
    p: &ConvParams,
    x: &[f32],
    w_skc: &[f32],
    stripe: &mut GridStripe<'_, f32>,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d;
    }
    let stage = &mut stage[..k * nb];
    brgemm_f32_with(uks, w_skc, a_offs, c, x, b_offs, w, stage, nb, k, nb, c, true);
    apply_block_staged(ops, bias, res_row, stage, k, q, pos, nb);
    stripe.store_block(stage);
}

/// Zero-allocation forward pass for one batch element: the tap-offset
/// tables live in caller-owned scratch (`a_offs` from
/// [`forward_a_offs`], `b_offs` any `S`-length buffer).
///
/// * `x`: `(C, W)` input row (`w` pre-padded), row-major, `x.len() == c*w`
/// * `w_skc`: weight relaid out to `(S, K, C)` ([`super::layout::kcs_to_skc`])
/// * `out`: `(K, Q)` output row, overwritten.
pub fn forward_single_into(
    p: &ConvParams,
    x: &[f32],
    w_skc: &[f32],
    out: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
) {
    forward_single_post_into(p, x, w_skc, out, a_offs, b_offs, &PostOps::none(), &[], None);
}

/// [`forward_single_into`] with the post-op epilogue fused into the width
/// block loop: each freshly-computed `(K, nb)` output block gets
/// bias/activation/residual/scale applied while it is still cache-hot —
/// one pass over the output instead of separate sweeps (DESIGN.md §5b).
/// `res_row` is this image's `(K, Q)` residual row when `ops.residual`.
#[allow(clippy::too_many_arguments)]
pub fn forward_single_post_into(
    p: &ConvParams,
    x: &[f32],
    w_skc: &[f32],
    out: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
) {
    let (c, k, s, w, q) = (p.c, p.k, p.s, p.w, p.q());
    debug_assert_eq!(p.stride, 1, "kernels compute at stride 1");
    debug_assert_eq!(x.len(), c * w);
    debug_assert_eq!(w_skc.len(), s * k * c);
    debug_assert_eq!(out.len(), k * q);
    debug_assert_eq!(a_offs.len(), s);
    debug_assert_eq!(b_offs.len(), s);
    let uks = simd::active();
    let mut pos = 0;
    while pos < q {
        let nb = WIDTH_BLOCK.min(q - pos);
        forward_block(uks, p, x, w_skc, out, a_offs, b_offs, ops, bias, res_row, pos, nb);
        pos += nb;
    }
}

/// Forward pass for one batch element (allocating convenience wrapper
/// around [`forward_single_into`]).
pub fn forward_single(p: &ConvParams, x: &[f32], w_skc: &[f32], out: &mut [f32]) {
    let a_offs = forward_a_offs(p);
    let mut b_offs = vec![0usize; p.s];
    forward_single_into(p, x, w_skc, out, &a_offs, &mut b_offs);
}

/// Batched forward pass with caller-owned scratch — the plan executor's
/// entry point. `b_offs` must hold at least one `S`-window per effective
/// worker (`min(ctx.threads, N)` for batch partitioning,
/// `min(ctx.threads, N·ceil(Q/64))` for grid); under [`Partition::Grid`]
/// `stage` must additionally hold one `K·WIDTH_BLOCK` f32 staging window
/// per effective worker (unused — may be empty — under
/// [`Partition::Batch`]). With `ctx.threads <= 1` the call performs zero
/// heap allocations.
#[allow(clippy::too_many_arguments)]
pub fn forward_with_scratch(
    p: &ConvParams,
    x: &[f32],
    w_skc: &[f32],
    out: &mut [f32],
    ctx: ExecCtx,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
) {
    forward_post_with_scratch(
        p,
        x,
        w_skc,
        out,
        ctx,
        a_offs,
        b_offs,
        stage,
        &PostOps::none(),
        &[],
        None,
    );
}

/// Batched fused-epilogue forward pass with caller-owned scratch — the
/// plan executor's post-op entry point. `residual` is the full `(N, K, Q)`
/// residual tensor when `ops.residual`; each worker sees only its image's
/// row. Zero heap allocations with `ctx.threads <= 1`, same as
/// [`forward_with_scratch`].
#[allow(clippy::too_many_arguments)]
pub fn forward_post_with_scratch(
    p: &ConvParams,
    x: &[f32],
    w_skc: &[f32],
    out: &mut [f32],
    ctx: ExecCtx,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    debug_assert_eq!(p.stride, 1, "kernels compute at stride 1");
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(w_skc.len(), s * k * c, "weight shape mismatch for {p}");
    assert_eq!(out.len(), n * k * q, "output shape mismatch for {p}");
    super::post::validate_args(ops, bias, residual, n, k, q);
    let uks = ctx.uks;
    let mut no_scratch: [f32; 0] = [];
    let res_of = |i: usize| {
        residual
            .filter(|_| ops.residual)
            .map(|r| &r[i * k * q..(i + 1) * k * q])
    };
    match ctx.partition {
        Partition::Batch => par_batch_chunks_scratch(
            out,
            k * q,
            b_offs,
            s,
            &mut no_scratch[..],
            0,
            ctx.threads,
            |i, out_row, bo, _| {
                let xrow = &x[i * c * w..(i + 1) * c * w];
                let res_row = res_of(i);
                let mut pos = 0;
                while pos < q {
                    let nb = WIDTH_BLOCK.min(q - pos);
                    forward_block(
                        uks, p, xrow, w_skc, out_row, a_offs, bo, ops, bias, res_row, pos, nb,
                    );
                    pos += nb;
                }
            },
        ),
        Partition::Grid => par_grid_chunks_scratch(
            out,
            k * q,
            q,
            WIDTH_BLOCK,
            b_offs,
            s,
            stage,
            k * WIDTH_BLOCK,
            ctx.threads,
            |i, pos, nb, stripe, bo, stg| {
                let xrow = &x[i * c * w..(i + 1) * c * w];
                let res_row = res_of(i);
                forward_block_grid(
                    uks, p, xrow, w_skc, stripe, a_offs, bo, stg, ops, bias, res_row, pos, nb,
                );
            },
        ),
    }
}

/// Batched forward pass, multithreaded across the batch dimension
/// (the paper's threading strategy, Sec. 2). The per-image offset tables
/// are hoisted: one scratch allocation per call, not per image.
///
/// * `x`: `(N, C, W)`; `out`: `(N, K, Q)`, overwritten.
pub fn forward(p: &ConvParams, x: &[f32], w_skc: &[f32], out: &mut [f32], threads: usize) {
    let a_offs = forward_a_offs(p);
    let workers = threads.max(1).min(p.n.max(1));
    let mut b_offs = vec![0usize; workers * p.s];
    let mut stage: [f32; 0] = []; // batch partitioning needs no staging
    forward_with_scratch(
        p,
        x,
        w_skc,
        out,
        ExecCtx::with_threads(threads),
        &a_offs,
        &mut b_offs,
        &mut stage,
    );
}

/// Forward pass with a caller-chosen width block — the ablation hook for
/// the paper's fixed block length of 64 (Sec. 3: "we keep the block length
/// equal to 64 elements"). Blocks other than 64 bypass the n=64
/// register-resident fast path, which is itself part of what the ablation
/// measures. `wb ≤ 128` (the generic micro-kernel's accumulator bound).
pub fn forward_single_wb(p: &ConvParams, x: &[f32], w_skc: &[f32], out: &mut [f32], wb: usize) {
    assert!(wb >= 1 && wb <= crate::conv1d::gemm::MAX_N);
    let (c, k, s, d, w, q) = (p.c, p.k, p.s, p.d, p.w, p.q());
    debug_assert_eq!(x.len(), c * w);
    debug_assert_eq!(w_skc.len(), s * k * c);
    debug_assert_eq!(out.len(), k * q);
    let uks = simd::active();
    let a_offs = forward_a_offs(p);
    let mut b_offs = vec![0usize; s];
    let mut pos = 0;
    while pos < q {
        let nb = wb.min(q - pos);
        for (is, bo) in b_offs.iter_mut().enumerate() {
            *bo = pos + is * d;
        }
        brgemm_f32_with(
            uks, w_skc, &a_offs, c, x, &b_offs, w, &mut out[pos..], q, k, nb, c, true,
        );
        pos += nb;
    }
}

/// Zero-allocation bf16 forward pass for one batch element: bf16
/// operands, f32 accumulate, bf16 store (paper Sec. 4.3 BF16 path; Cooper
/// Lake `VDPBF16PS`). `fblock` is the caller-owned `K·WIDTH_BLOCK` f32
/// accumulator staging block narrowed to bf16 on store (row-wise chunked
/// narrowing, [`super::bf16::narrow_row_into`]).
pub fn forward_single_bf16_into(
    p: &ConvParams,
    x: &[Bf16],
    w_skc: &[Bf16],
    out: &mut [Bf16],
    a_offs: &[usize],
    b_offs: &mut [usize],
    fblock: &mut [f32],
) {
    let (c, k, s, d, w, q) = (p.c, p.k, p.s, p.d, p.w, p.q());
    debug_assert_eq!(x.len(), c * w);
    debug_assert_eq!(w_skc.len(), s * k * c);
    debug_assert_eq!(out.len(), k * q);
    debug_assert_eq!(a_offs.len(), s);
    debug_assert_eq!(b_offs.len(), s);
    debug_assert!(fblock.len() >= k * WIDTH_BLOCK);
    let uks = simd::active();
    let mut pos = 0;
    while pos < q {
        let nb = WIDTH_BLOCK.min(q - pos);
        for (is, bo) in b_offs.iter_mut().enumerate() {
            *bo = pos + is * d;
        }
        brgemm_bf16_with(uks, w_skc, a_offs, c, x, b_offs, w, fblock, nb, k, nb, c, true);
        // Narrow the f32 accumulator block to bf16 on store, row by row.
        for ik in 0..k {
            narrow_row_into(
                &fblock[ik * nb..(ik + 1) * nb],
                &mut out[ik * q + pos..ik * q + pos + nb],
            );
        }
        pos += nb;
    }
}

/// bf16 forward pass for one batch element (allocating wrapper).
pub fn forward_single_bf16(p: &ConvParams, x: &[Bf16], w_skc: &[Bf16], out: &mut [Bf16]) {
    let a_offs = forward_a_offs(p);
    let mut b_offs = vec![0usize; p.s];
    let mut fblock = vec![0.0f32; p.k * WIDTH_BLOCK];
    forward_single_bf16_into(p, x, w_skc, out, &a_offs, &mut b_offs, &mut fblock);
}

/// Batched bf16 forward pass. Offset tables and the f32 accumulator block
/// are hoisted to one allocation per worker, not one per image.
pub fn forward_bf16(p: &ConvParams, x: &[Bf16], w_skc: &[Bf16], out: &mut [Bf16], threads: usize) {
    let (n, c, k, w, q) = (p.n, p.c, p.k, p.w, p.q());
    assert_eq!(x.len(), n * c * w);
    assert_eq!(w_skc.len(), p.s * k * c);
    assert_eq!(out.len(), n * k * q);
    let a_offs = forward_a_offs(p);
    let workers = threads.max(1).min(n.max(1));
    let mut b_offs = vec![0usize; workers * p.s];
    let mut fblock = vec![0.0f32; workers * k * WIDTH_BLOCK];
    par_batch_chunks_scratch(
        out,
        k * q,
        &mut b_offs[..],
        p.s,
        &mut fblock[..],
        k * WIDTH_BLOCK,
        threads,
        |i, out_row, bo, fb| {
            forward_single_bf16_into(
                p,
                &x[i * c * w..(i + 1) * c * w],
                w_skc,
                out_row,
                &a_offs,
                bo,
                fb,
            );
        },
    );
}

/// One bf16-operand `(K, nb)` output block with f32 output — the unit of
/// work of the plan's bf16 kernel under both partitionings.
#[allow(clippy::too_many_arguments)]
#[inline]
fn forward_block_bf16_f32out(
    uks: &MicroKernelSet,
    p: &ConvParams,
    x: &[Bf16],
    w_skc: &[Bf16],
    out_row: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d;
    }
    brgemm_bf16_with(
        uks,
        w_skc,
        a_offs,
        c,
        x,
        b_offs,
        w,
        &mut out_row[pos..],
        q,
        k,
        nb,
        c,
        true,
    );
    apply_block(ops, bias, res_row, out_row, k, q, pos, nb);
}

/// [`forward_block_bf16_f32out`] for a grid worker — staged like
/// [`forward_block_grid`]: BRGEMM into the worker's private `(K, nb)`
/// block, epilogue on the hot block, stripe-only store through the
/// [`GridStripe`] handle.
#[allow(clippy::too_many_arguments)]
#[inline]
fn forward_block_grid_bf16_f32out(
    uks: &MicroKernelSet,
    p: &ConvParams,
    x: &[Bf16],
    w_skc: &[Bf16],
    stripe: &mut GridStripe<'_, f32>,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d;
    }
    let stage = &mut stage[..k * nb];
    brgemm_bf16_with(uks, w_skc, a_offs, c, x, b_offs, w, stage, nb, k, nb, c, true);
    apply_block_staged(ops, bias, res_row, stage, k, q, pos, nb);
    stripe.store_block(stage);
}

/// Zero-allocation bf16 forward with **f32 output** — the plan executor's
/// bf16 kernel: operands stay bf16 (`VDPBF16PS` semantics), the f32
/// accumulator is stored directly, so the caller keeps a uniform f32
/// tensor interface across precisions.
#[allow(clippy::too_many_arguments)]
pub fn forward_bf16_f32out_with_scratch(
    p: &ConvParams,
    x: &[Bf16],
    w_skc: &[Bf16],
    out: &mut [f32],
    ctx: ExecCtx,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
) {
    forward_bf16_f32out_post_with_scratch(
        p,
        x,
        w_skc,
        out,
        ctx,
        a_offs,
        b_offs,
        stage,
        &PostOps::none(),
        &[],
        None,
    );
}

/// [`forward_bf16_f32out_with_scratch`] with the post-op epilogue fused
/// into the width block loop (applied to the f32 accumulator block right
/// after its BRGEMM, before the next block is computed).
#[allow(clippy::too_many_arguments)]
pub fn forward_bf16_f32out_post_with_scratch(
    p: &ConvParams,
    x: &[Bf16],
    w_skc: &[Bf16],
    out: &mut [f32],
    ctx: ExecCtx,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    debug_assert_eq!(p.stride, 1, "kernels compute at stride 1");
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(w_skc.len(), s * k * c, "weight shape mismatch for {p}");
    assert_eq!(out.len(), n * k * q, "output shape mismatch for {p}");
    super::post::validate_args(ops, bias, residual, n, k, q);
    let uks = ctx.uks;
    let mut no_scratch: [f32; 0] = [];
    let res_of = |i: usize| {
        residual
            .filter(|_| ops.residual)
            .map(|r| &r[i * k * q..(i + 1) * k * q])
    };
    match ctx.partition {
        Partition::Batch => par_batch_chunks_scratch(
            out,
            k * q,
            b_offs,
            s,
            &mut no_scratch[..],
            0,
            ctx.threads,
            |i, out_row, bo, _| {
                let xrow = &x[i * c * w..(i + 1) * c * w];
                let res_row = res_of(i);
                let mut pos = 0;
                while pos < q {
                    let nb = WIDTH_BLOCK.min(q - pos);
                    forward_block_bf16_f32out(
                        uks, p, xrow, w_skc, out_row, a_offs, bo, ops, bias, res_row, pos, nb,
                    );
                    pos += nb;
                }
            },
        ),
        Partition::Grid => par_grid_chunks_scratch(
            out,
            k * q,
            q,
            WIDTH_BLOCK,
            b_offs,
            s,
            stage,
            k * WIDTH_BLOCK,
            ctx.threads,
            |i, pos, nb, stripe, bo, stg| {
                let xrow = &x[i * c * w..(i + 1) * c * w];
                let res_row = res_of(i);
                forward_block_grid_bf16_f32out(
                    uks, p, xrow, w_skc, stripe, a_offs, bo, stg, ops, bias, res_row, pos, nb,
                );
            },
        ),
    }
}

/// Reinterpret an i32 scratch window as f32 storage — the grid arm of the
/// i8 kernel stages its dequantized block in the upper half of its single
/// typed scratch window (the partitioning substrate hands out exactly two
/// typed scratch slots per worker, and the offset table takes one).
fn as_f32_mut(v: &mut [i32]) -> &mut [f32] {
    // SAFETY: i32 and f32 have identical size and alignment and every bit
    // pattern is a valid value of either type; the exclusive borrow is
    // passed through unchanged, so no aliasing is introduced.
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut f32, v.len()) }
}

/// One i8-operand `(K, nb)` output block with f32 output — the unit of
/// work of the plan's i8 kernel under [`Partition::Batch`]. The BRGEMM
/// accumulates exactly in the worker's private i32 staging block
/// (`ldc = nb`), each accumulator row is dequantized into the output row
/// with its channel's combined scale `deq[k] = scale_x · scale_w[k]`, and
/// the f32 post-op epilogue runs on the freshly-stored block — the
/// "requantize at the fusion boundary" contract: everything downstream of
/// the integer GEMM is ordinary f32.
#[allow(clippy::too_many_arguments)]
#[inline]
fn forward_block_i8_f32out(
    uks: &MicroKernelSet,
    p: &ConvParams,
    x: &[i8],
    w_skc: &[i8],
    deq: &[f32],
    out_row: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
    iacc: &mut [i32],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d;
    }
    let iacc = &mut iacc[..k * nb];
    brgemm_i8_with(uks, w_skc, a_offs, c, x, b_offs, w, iacc, nb, k, nb, c, true);
    for ik in 0..k {
        let dq = deq[ik];
        let src = &iacc[ik * nb..(ik + 1) * nb];
        let dst = &mut out_row[ik * q + pos..ik * q + pos + nb];
        for (o, &acc) in dst.iter_mut().zip(src) {
            *o = acc as f32 * dq;
        }
    }
    apply_block(ops, bias, res_row, out_row, k, q, pos, nb);
}

/// [`forward_block_i8_f32out`] for a grid worker: the worker's single i32
/// scratch window is split in half — BRGEMM accumulates into the lower
/// `K·nb` i32 block, the upper half (viewed as f32) receives the
/// dequantized block, the epilogue runs on that hot f32 block, and only
/// the worker's own column stripe is stored through the [`GridStripe`]
/// handle. Integer accumulation is exact, so grid output is bit-identical
/// to batch for free — no `ldc` caveat even applies.
#[allow(clippy::too_many_arguments)]
#[inline]
fn forward_block_grid_i8_f32out(
    uks: &MicroKernelSet,
    p: &ConvParams,
    x: &[i8],
    w_skc: &[i8],
    deq: &[f32],
    stripe: &mut GridStripe<'_, f32>,
    a_offs: &[usize],
    b_offs: &mut [usize],
    iacc2: &mut [i32],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d;
    }
    let (iacc, fraw) = iacc2.split_at_mut(k * WIDTH_BLOCK);
    let iacc = &mut iacc[..k * nb];
    let stage = &mut as_f32_mut(fraw)[..k * nb];
    brgemm_i8_with(uks, w_skc, a_offs, c, x, b_offs, w, iacc, nb, k, nb, c, true);
    for ik in 0..k {
        let dq = deq[ik];
        for (o, &acc) in stage[ik * nb..(ik + 1) * nb].iter_mut().zip(&iacc[ik * nb..]) {
            *o = acc as f32 * dq;
        }
    }
    apply_block_staged(ops, bias, res_row, stage, k, q, pos, nb);
    stripe.store_block(stage);
}

/// Batched i8 forward with **f32 output** and the post-op epilogue fused
/// into the width-block loop — the plan executor's i8 kernel. Operands are
/// already quantized (`x` per-tensor, `w_skc` per-output-channel — the
/// plan stages both); `deq[k] = scale_x · scale_w[k]` is the combined
/// dequantization scale per output channel. `iacc` must hold
/// `K·WIDTH_BLOCK` i32 per effective worker under [`Partition::Batch`]
/// and `2·K·WIDTH_BLOCK` under [`Partition::Grid`] (accumulator + staged
/// f32 halves). Zero heap allocations with `ctx.threads <= 1`.
#[allow(clippy::too_many_arguments)]
pub fn forward_i8_f32out_post_with_scratch(
    p: &ConvParams,
    x: &[i8],
    w_skc: &[i8],
    deq: &[f32],
    out: &mut [f32],
    ctx: ExecCtx,
    a_offs: &[usize],
    b_offs: &mut [usize],
    iacc: &mut [i32],
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    debug_assert_eq!(p.stride, 1, "kernels compute at stride 1");
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(w_skc.len(), s * k * c, "weight shape mismatch for {p}");
    assert_eq!(out.len(), n * k * q, "output shape mismatch for {p}");
    assert_eq!(deq.len(), k, "one dequantization scale per output channel");
    super::post::validate_args(ops, bias, residual, n, k, q);
    let uks = ctx.uks;
    let res_of = |i: usize| {
        residual
            .filter(|_| ops.residual)
            .map(|r| &r[i * k * q..(i + 1) * k * q])
    };
    match ctx.partition {
        Partition::Batch => par_batch_chunks_scratch(
            out,
            k * q,
            b_offs,
            s,
            iacc,
            k * WIDTH_BLOCK,
            ctx.threads,
            |i, out_row, bo, ia| {
                let xrow = &x[i * c * w..(i + 1) * c * w];
                let res_row = res_of(i);
                let mut pos = 0;
                while pos < q {
                    let nb = WIDTH_BLOCK.min(q - pos);
                    forward_block_i8_f32out(
                        uks, p, xrow, w_skc, deq, out_row, a_offs, bo, ia, ops, bias, res_row,
                        pos, nb,
                    );
                    pos += nb;
                }
            },
        ),
        Partition::Grid => par_grid_chunks_scratch(
            out,
            k * q,
            q,
            WIDTH_BLOCK,
            b_offs,
            s,
            iacc,
            2 * k * WIDTH_BLOCK,
            ctx.threads,
            |i, pos, nb, stripe, bo, ia| {
                let xrow = &x[i * c * w..(i + 1) * c * w];
                let res_row = res_of(i);
                forward_block_grid_i8_f32out(
                    uks, p, xrow, w_skc, deq, stripe, a_offs, bo, ia, ops, bias, res_row, pos, nb,
                );
            },
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::direct::forward_direct;
    use crate::conv1d::layout::kcs_to_skc;
    use crate::conv1d::test_util::rnd;

    fn check(p: ConvParams) {
        let x = rnd(p.n * p.c * p.w, 11);
        let wt = rnd(p.k * p.c * p.s, 22);
        let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
        let mut got = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut got, 1);
        let mut want = vec![0.0; p.n * p.k * p.q()];
        forward_direct(&p, &x, &wt, &mut want);
        for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
            assert!((g - w_).abs() < 1e-4 * (1.0 + w_.abs()), "{p} idx {i}: {g} vs {w_}");
        }
    }

    #[test]
    fn matches_direct_paper_shapes() {
        for &(n, c, k, q, s, d) in &[
            (2, 15, 15, 128, 51, 8), // AtacWorks layer
            (1, 64, 64, 200, 5, 1),  // Fig. 5 family
            (2, 32, 32, 130, 9, 4),  // Fig. 6 family
            (1, 1, 1, 64, 1, 1),     // degenerate
            (1, 4, 8, 100, 15, 2),   // Q % 64 != 0
            (3, 10, 16, 77, 21, 1),
            (1, 8, 4, 640, 25, 16),
        ] {
            check(ConvParams::new(n, c, k, q + (s - 1) * d, s, d).unwrap());
        }
    }

    #[test]
    fn multithreaded_equals_single() {
        let p = ConvParams::new(4, 6, 7, 300, 9, 3).unwrap();
        let x = rnd(p.n * p.c * p.w, 33);
        let wt = rnd(p.k * p.c * p.s, 44);
        let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
        let mut o1 = vec![0.0; p.n * p.k * p.q()];
        let mut o4 = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut o1, 1);
        forward(&p, &x, &skc, &mut o4, 4);
        assert_eq!(o1, o4, "threading must be bit-exact");
    }

    #[test]
    fn grid_partition_equals_batch_bit_exact() {
        // The 2D (batch × width-block) partitioning must reproduce the
        // batch split bit for bit — including N=1, where only the grid
        // actually fans out. Mirrors `multithreaded_equals_single`.
        for &(n, threads) in &[(1usize, 8usize), (3, 4), (2, 1)] {
            let p = ConvParams::new(n, 6, 7, 400, 9, 3).unwrap();
            let x = rnd(p.n * p.c * p.w, 53);
            let wt = rnd(p.k * p.c * p.s, 54);
            let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
            let a_offs = forward_a_offs(&p);
            let run = |partition| {
                let ctx = ExecCtx::new(threads, partition);
                let workers = threads.max(1); // enough for either split
                let mut b_offs = vec![0usize; workers * p.s];
                let mut stage = vec![0.0f32; workers * p.k * WIDTH_BLOCK];
                let mut out = vec![0.0; p.n * p.k * p.q()];
                forward_with_scratch(&p, &x, &skc, &mut out, ctx, &a_offs, &mut b_offs, &mut stage);
                out
            };
            assert_eq!(
                run(Partition::Batch),
                run(Partition::Grid),
                "N={n} threads={threads}: grid must be bit-exact vs batch"
            );
        }
    }

    #[test]
    fn i8_grid_equals_batch_bit_exact_and_matches_dequant_oracle() {
        use crate::conv1d::layout::kcs_to_skc_i8;
        use crate::conv1d::quant::{absmax, channel_scales_kcs, quantize_into, scale_from_absmax};
        for &(n, threads) in &[(1usize, 8usize), (3, 4), (2, 1)] {
            let p = ConvParams::new(n, 6, 7, 400, 9, 3).unwrap();
            let x = rnd(p.n * p.c * p.w, 57);
            let wt = rnd(p.k * p.c * p.s, 58);
            let sx = scale_from_absmax(absmax(&x));
            let w_scales = channel_scales_kcs(&wt, p.k, p.c, p.s);
            let mut xq = vec![0i8; x.len()];
            quantize_into(&x, sx, &mut xq);
            let mut wq = vec![0i8; wt.len()];
            for k in 0..p.k {
                quantize_into(
                    &wt[k * p.c * p.s..(k + 1) * p.c * p.s],
                    w_scales[k],
                    &mut wq[k * p.c * p.s..(k + 1) * p.c * p.s],
                );
            }
            let skc_q = kcs_to_skc_i8(&wq, p.k, p.c, p.s);
            let deq: Vec<f32> = w_scales.iter().map(|&ws| sx * ws).collect();
            let a_offs = forward_a_offs(&p);
            let run = |partition| {
                let ctx = ExecCtx::new(threads, partition);
                let workers = threads.max(1);
                let mut b_offs = vec![0usize; workers * p.s];
                let mut iacc = vec![0i32; workers * 2 * p.k * WIDTH_BLOCK];
                let mut out = vec![0.0f32; p.n * p.k * p.q()];
                forward_i8_f32out_post_with_scratch(
                    &p,
                    &xq,
                    &skc_q,
                    &deq,
                    &mut out,
                    ctx,
                    &a_offs,
                    &mut b_offs,
                    &mut iacc,
                    &PostOps::none(),
                    &[],
                    None,
                );
                out
            };
            let batch = run(Partition::Batch);
            assert_eq!(
                batch.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                run(Partition::Grid).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "N={n} threads={threads}: i8 grid must be bit-exact vs batch"
            );
            // Exact dequantization oracle: direct conv over the *dequantized*
            // operands must match within f32 rounding of the dequant multiply.
            let xdq: Vec<f32> = xq.iter().map(|&v| v as f32 * sx).collect();
            let wdq: Vec<f32> = wq
                .iter()
                .enumerate()
                .map(|(i, &v)| v as f32 * w_scales[i / (p.c * p.s)])
                .collect();
            let mut want = vec![0.0f32; p.n * p.k * p.q()];
            forward_direct(&p, &xdq, &wdq, &mut want);
            for (g, w_) in batch.iter().zip(&want) {
                assert!((g - w_).abs() < 1e-3 * (1.0 + w_.abs()), "{g} vs {w_}");
            }
        }
    }

    #[test]
    fn bf16_close_to_f32() {
        use crate::conv1d::bf16::{to_bf16, to_f32};
        let p = ConvParams::new(2, 16, 16, 160, 5, 2).unwrap();
        let x = rnd(p.n * p.c * p.w, 55);
        let wt = rnd(p.k * p.c * p.s, 66);
        let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
        let mut f32_out = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut f32_out, 1);
        let mut bf_out = vec![Bf16::ZERO; p.n * p.k * p.q()];
        forward_bf16(&p, &to_bf16(&x), &to_bf16(&skc), &mut bf_out, 1);
        for (g, w_) in to_f32(&bf_out).iter().zip(&f32_out) {
            assert!((g - w_).abs() < 4e-2 * (1.0 + w_.abs()), "{g} vs {w_}");
        }
    }

    #[test]
    fn identity_filter() {
        // S=1, C=K=1, weight 1.0 → output == input.
        let p = ConvParams::new(1, 1, 1, 100, 1, 7).unwrap();
        let x = rnd(100, 77);
        let mut out = vec![0.0; 100];
        forward(&p, &x, &[1.0], &mut out, 1);
        assert_eq!(out, x);
    }
}
