//! Naive direct convolution — correctness oracle and worst-case baseline.
//!
//! The plain 6-nested-loop evaluation of paper eq. (2). It performs no
//! blocking, no layout transformation and no vector-friendly access
//! pattern, so it doubles as the "unoptimised" end of the efficiency
//! spectrum in the benchmark harness (the shape oneDNN's 1D path collapses
//! to for long widths and filters).

use super::params::ConvParams;
use super::post::{apply_segment, PostOps};

/// Forward: `Out[n,k,q] = Σ_c Σ_s In[n,c,q+d·s] · W[k,c,s]` (weight in
/// framework layout `(K, C, S)`). `out` is overwritten.
pub fn forward_direct(p: &ConvParams, x: &[f32], w_kcs: &[f32], out: &mut [f32]) {
    forward_direct_post(p, x, w_kcs, out, &PostOps::none(), &[], None);
}

/// [`forward_direct`] with the post-op epilogue fused per output row: the
/// `(n, k)` row is complete after the `c`/`s` accumulation loops, so the
/// epilogue runs on it before the next row is touched — one pass over the
/// output even in the oracle kernel.
pub fn forward_direct_post(
    p: &ConvParams,
    x: &[f32],
    w_kcs: &[f32],
    out: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
) {
    let (n, c, k, s, d, w, q) = (p.n, p.c, p.k, p.s, p.d, p.w, p.q());
    debug_assert_eq!(p.stride, 1, "kernels compute at stride 1");
    assert_eq!(x.len(), n * c * w);
    assert_eq!(w_kcs.len(), k * c * s);
    assert_eq!(out.len(), n * k * q);
    super::post::validate_args(ops, bias, residual, n, k, q);
    out.fill(0.0);
    for ib in 0..n {
        for ik in 0..k {
            let row = (ib * k + ik) * q;
            for ic in 0..c {
                for is in 0..s {
                    let wv = w_kcs[(ik * c + ic) * s + is];
                    let xrow = &x[(ib * c + ic) * w + is * d..(ib * c + ic) * w + is * d + q];
                    let orow = &mut out[row..row + q];
                    for iq in 0..q {
                        orow[iq] += wv * xrow[iq];
                    }
                }
            }
            if !ops.is_none() {
                let bias_k = if ops.bias { bias[ik] } else { 0.0 };
                let res = residual
                    .filter(|_| ops.residual)
                    .map(|r| &r[row..row + q]);
                apply_segment(ops, bias_k, res, &mut out[row..row + q]);
            }
        }
    }
}

/// Backward-data: scatter-style adjoint of [`forward_direct`].
pub fn backward_data_direct(p: &ConvParams, gout: &[f32], w_kcs: &[f32], gin: &mut [f32]) {
    let (n, c, k, s, d, w, q) = (p.n, p.c, p.k, p.s, p.d, p.w, p.q());
    assert_eq!(gout.len(), n * k * q);
    assert_eq!(gin.len(), n * c * w);
    gin.fill(0.0);
    for ib in 0..n {
        for ik in 0..k {
            for ic in 0..c {
                for is in 0..s {
                    let wv = w_kcs[(ik * c + ic) * s + is];
                    for iq in 0..q {
                        gin[(ib * c + ic) * w + iq + is * d] += wv * gout[(ib * k + ik) * q + iq];
                    }
                }
            }
        }
    }
}

/// Backward-weight into a caller-owned `(K, C, S)` buffer:
/// `Grad_w[k,c,s] = Σ_n Σ_q Grad_out[n,k,q] · In[n,c,q+d·s]`.
pub fn backward_weight_direct_into(p: &ConvParams, gout: &[f32], x: &[f32], gw: &mut [f32]) {
    let (n, c, k, s, d, w, q) = (p.n, p.c, p.k, p.s, p.d, p.w, p.q());
    assert_eq!(gout.len(), n * k * q);
    assert_eq!(x.len(), n * c * w);
    assert_eq!(gw.len(), k * c * s);
    gw.fill(0.0);
    for ib in 0..n {
        for ik in 0..k {
            for ic in 0..c {
                for is in 0..s {
                    let mut acc = 0.0f32;
                    let grow = &gout[(ib * k + ik) * q..(ib * k + ik) * q + q];
                    let xrow = &x[(ib * c + ic) * w + is * d..(ib * c + ic) * w + is * d + q];
                    for iq in 0..q {
                        acc += grow[iq] * xrow[iq];
                    }
                    gw[(ik * c + ic) * s + is] += acc;
                }
            }
        }
    }
}

/// Backward-weight returning a fresh `(K, C, S)` gradient buffer.
pub fn backward_weight_direct(p: &ConvParams, gout: &[f32], x: &[f32]) -> Vec<f32> {
    let mut gw = vec![0.0f32; p.k * p.c * p.s];
    backward_weight_direct_into(p, gout, x, &mut gw);
    gw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_example() {
        // Fig. 1-style tiny case: C=1, K=1, S=2, d=2, W=6 -> Q=4.
        // x = [1 2 3 4 5 6], w = [10, 1]: out[q] = 10*x[q] + x[q+2].
        let p = ConvParams::new(1, 1, 1, 6, 2, 2).unwrap();
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [10.0, 1.0];
        let mut out = vec![0.0; 4];
        forward_direct(&p, &x, &w, &mut out);
        assert_eq!(out, vec![13.0, 24.0, 35.0, 46.0]);
    }

    #[test]
    fn backward_data_hand_example() {
        let p = ConvParams::new(1, 1, 1, 6, 2, 2).unwrap();
        let w = [10.0, 1.0];
        let gout = [1.0, 1.0, 1.0, 1.0];
        let mut gin = vec![0.0; 6];
        backward_data_direct(&p, &gout, &w, &mut gin);
        // gin[w] = 10*gout[w] (if w<4) + 1*gout[w-2] (if 2<=w<6)
        assert_eq!(gin, vec![10.0, 10.0, 11.0, 11.0, 1.0, 1.0]);
    }

    #[test]
    fn backward_weight_is_finite_difference_of_forward() {
        // Central-difference gradient check of the forward pass.
        let p = ConvParams::new(1, 2, 2, 20, 3, 2).unwrap();
        let x: Vec<f32> = (0..p.c * p.w).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut wt: Vec<f32> = (0..p.k * p.c * p.s).map(|i| (i as f32 * 0.3).cos()).collect();
        let gout: Vec<f32> = (0..p.k * p.q()).map(|i| 0.1 + (i % 5) as f32 * 0.2).collect();
        let gw = backward_weight_direct(&p, &gout, &x);
        let eps = 1e-2f32;
        let mut out_p = vec![0.0; p.k * p.q()];
        let mut out_m = vec![0.0; p.k * p.q()];
        for wi in 0..wt.len() {
            let orig = wt[wi];
            wt[wi] = orig + eps;
            forward_direct(&p, &x, &wt, &mut out_p);
            wt[wi] = orig - eps;
            forward_direct(&p, &x, &wt, &mut out_m);
            wt[wi] = orig;
            // d/dw <gout, Out> = gw[wi]
            let fd: f32 = out_p
                .iter()
                .zip(&out_m)
                .zip(&gout)
                .map(|((a, b), g)| (a - b) / (2.0 * eps) * g)
                .sum();
            assert!(
                (fd - gw[wi]).abs() < 2e-2 * (1.0 + gw[wi].abs()),
                "w[{wi}]: fd {fd} vs analytic {}",
                gw[wi]
            );
        }
    }
}
