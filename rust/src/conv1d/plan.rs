//! Plan/executor API — the cuDNN/oneDNN-style *setup-once, run-many*
//! surface of the conv1d layer (DESIGN.md §5a).
//!
//! The paper's LIBXSMM layer JITs its BRGEMM kernels and relays the
//! weight tensor out **once at construction**, then reuses scratch every
//! step. This module reproduces that shape natively:
//!
//! * [`ConvKernel`] — the backend contract (forward / backward-data /
//!   backward-weight + capability and workspace queries), implemented by
//!   the BRGEMM, im2col, direct and bf16 kernels;
//! * the **registry** ([`kernels`], [`lookup_kernel`]) — string-named
//!   kernel lookup, so configs, benches and CLIs select backends without
//!   touching an enum;
//! * [`ConvPlan`] — built once from `ConvParams` + backend + precision;
//!   owns the derived weight layouts, the precomputed tap-offset tables,
//!   the padding geometry and a [`Workspace`], so the steady-state
//!   `execute_*_into` calls perform **zero** heap allocations
//!   (single-worker plans; multi-worker plans allocate only the scoped
//!   thread spawns — asserted by `tests/plan_alloc.rs`).

use super::backward_data::{backward_data_a_offs, backward_data_with_scratch};
use super::backward_weight::backward_weight_with_scratch;
use super::bf16::{to_bf16, to_bf16_into, Bf16};
use super::direct::{backward_data_direct, backward_weight_direct_into, forward_direct_post};
use super::forward::{
    forward_a_offs, forward_bf16_f32out_post_with_scratch, forward_i8_f32out_post_with_scratch,
    forward_post_with_scratch, forward_with_scratch,
};
use super::im2col::forward_im2col_post_with_scratch;
use super::layer::Backend;
use super::layout::{
    kcs_to_sck_flipped_into, kcs_to_skc_into, pad_width_into, unpad_width_into,
};
use super::params::{ConvParams, WIDTH_BLOCK};
use super::post::{self, PostOps};
use super::quant;
use super::simd::{self, Isa, MicroKernelSet};
use super::threading::{ExecCtx, Partition};
use crate::dist::Placement;
use crate::machine::Precision;

/// Plan construction failure (invalid shape, unknown backend, or a
/// backend/precision combination the registry cannot serve).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanError(pub String);

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "conv plan error: {}", self.0)
    }
}

impl std::error::Error for PlanError {}

/// All derived weight layouts a plan owns (relayouts happen once at
/// construction / `set_weights`, never per step — paper Sec. 3.1/3.2).
pub struct PlanWeights {
    /// Framework layout `(K, C, S)` — im2col/direct operand.
    pub kcs: Vec<f32>,
    /// Forward layout `(S, K, C)` — BRGEMM operand.
    pub skc: Vec<f32>,
    /// Backward-data layout `(S, C, K)`, taps reversed.
    pub sck_flip: Vec<f32>,
    /// bf16 copy of the forward layout (bf16 plans only, else empty).
    pub skc_bf16: Vec<Bf16>,
    /// Per-output-channel symmetric int8 quantized forward layout
    /// (i8 plans only, else empty).
    pub skc_i8: Vec<i8>,
    /// Per-output-channel weight scales `absmax(K-row)/127`, all-zero
    /// rows guarded to 1.0 (i8 plans only, else empty).
    pub w_scales: Vec<f32>,
    /// Combined dequantization scales `input_scale · w_scales[k]` —
    /// what the i8 forward multiplies each i32 accumulator row by.
    pub deq: Vec<f32>,
    /// Per-tensor symmetric activation scale (calibrated absmax/127;
    /// 1.0 until [`ConvPlan::set_input_scale`] installs a calibration).
    pub input_scale: f32,
}

/// Element counts of every workspace buffer a kernel needs for a problem;
/// the single source of truth for both allocation and the
/// `workspace_bytes` query.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceSpec {
    /// Per-worker tap-offset windows (`workers·S`).
    pub b_offs: usize,
    /// Per-worker im2col patch matrices (`workers·C·S·Q`).
    pub col: usize,
    /// Padded output gradient for backward-data (`N·K·(Q + 2·(S−1)·d)`).
    pub gout_padded: usize,
    /// Per-worker backward-weight accumulators (`workers·S·C·K`).
    pub gw_partials: usize,
    /// Per-worker grid staging blocks (`workers·max(K,C)·WIDTH_BLOCK`):
    /// grid workers compute each width block into private contiguous
    /// staging and store only their own column stripe of the shared
    /// output row (no aliasing `&mut` across workers).
    pub stage: usize,
    /// bf16 staging copy of the input (`N·C·W`, bf16 kernel only).
    pub xb: usize,
    /// i8 staging copy of the quantized input (`N·C·W`, i8 kernel only).
    pub xq: usize,
    /// Per-worker i32 accumulator windows (`workers·2·K·WIDTH_BLOCK`,
    /// i8 kernel only): the i8 grid arm splits its window into an i32
    /// accumulator half and a dequantized-f32 staging half.
    pub iacc: usize,
    /// Padded-input scratch for same-padding execution (`N·C·W`). Zero in
    /// kernel specs — grown lazily on first `execute_forward_same_into`.
    pub padded_in: usize,
    /// Padded data-gradient scratch for same-padding backward (`N·C·W`).
    /// Zero in kernel specs — grown lazily on first use.
    pub gx_padded: usize,
    /// Owned output buffer (`N·K·Q`, the non-`_into` convenience API).
    /// Zero in kernel specs — grown lazily on first `execute_forward`.
    pub out: usize,
}

impl WorkspaceSpec {
    /// Total bytes the buffers occupy.
    pub fn bytes(&self) -> usize {
        (self.b_offs) * std::mem::size_of::<usize>()
            + (self.col
                + self.gout_padded
                + self.gw_partials
                + self.stage
                + self.padded_in
                + self.gx_padded
                + self.out
                + self.iacc)
                * 4
            + self.xb * 2
            + self.xq
    }
}

/// Caller-visible scratch of one plan: every buffer any executor touches,
/// sized once at plan construction.
pub struct Workspace {
    /// Forward tap offsets into the `(S, K, C)` weight (`S` entries).
    a_offs_fwd: Vec<usize>,
    /// Backward-data tap offsets into the `(S, C, K)` weight.
    a_offs_bwd: Vec<usize>,
    b_offs: Vec<usize>,
    col: Vec<f32>,
    gout_padded: Vec<f32>,
    gw_partials: Vec<f32>,
    /// Per-worker grid staging blocks (see [`WorkspaceSpec::stage`]).
    stage: Vec<f32>,
    xb: Vec<Bf16>,
    /// i8 staging copy of the quantized input (see [`WorkspaceSpec::xq`]).
    xq: Vec<i8>,
    /// Per-worker i32 accumulator windows (see [`WorkspaceSpec::iacc`]).
    iacc: Vec<i32>,
    padded_in: Vec<f32>,
    gx_padded: Vec<f32>,
    out: Vec<f32>,
    /// Fused-backward prologue buffer (`N·K·Q`): the activation-masked,
    /// scaled gradient the kernels consume. Grown lazily on first fused
    /// backward.
    gpre: Vec<f32>,
    /// Stride-1 staging output for `stride > 1` plans (`N·K·Q₁`). Grown
    /// lazily on first strided execution.
    full: Vec<f32>,
}

impl Workspace {
    fn from_spec(p: &ConvParams, spec: &WorkspaceSpec) -> Workspace {
        Workspace {
            a_offs_fwd: forward_a_offs(p),
            a_offs_bwd: backward_data_a_offs(p),
            b_offs: vec![0; spec.b_offs],
            col: vec![0.0; spec.col],
            gout_padded: vec![0.0; spec.gout_padded],
            gw_partials: vec![0.0; spec.gw_partials],
            stage: vec![0.0; spec.stage],
            xb: vec![Bf16::ZERO; spec.xb],
            xq: vec![0; spec.xq],
            iacc: vec![0; spec.iacc],
            padded_in: vec![0.0; spec.padded_in],
            gx_padded: vec![0.0; spec.gx_padded],
            out: vec![0.0; spec.out],
            gpre: Vec::new(),
            full: Vec::new(),
        }
    }

    /// Total bytes held by this workspace's scratch buffers.
    pub fn bytes(&self) -> usize {
        (self.a_offs_fwd.len() + self.a_offs_bwd.len() + self.b_offs.len())
            * std::mem::size_of::<usize>()
            + (self.col.len()
                + self.gout_padded.len()
                + self.gw_partials.len()
                + self.stage.len()
                + self.padded_in.len()
                + self.gx_padded.len()
                + self.out.len()
                + self.gpre.len()
                + self.full.len()
                + self.iacc.len())
                * 4
            + self.xb.len() * 2
            + self.xq.len()
    }
}

/// Effective worker count under batch partitioning (one scratch window
/// per worker): im2col's patch matrices are sized by this — the baseline
/// only shards across N.
fn workers_batch(p: &ConvParams, threads: usize) -> usize {
    threads.max(1).min(p.n.max(1))
}

/// Worker-count upper bound across *both* partitionings: the grid splits
/// `N × ceil(W/64)` cells (`W ≥ Q`, so this also covers the backward-data
/// grid over the data-gradient width). Grid-capable kernels size their
/// per-worker scratch by this, so one workspace serves either partition.
fn workers_grid(p: &ConvParams, threads: usize) -> usize {
    threads.max(1).min((p.n * p.w.div_ceil(WIDTH_BLOCK)).max(1))
}

/// Grow a lazily-sized workspace buffer to its target length. A no-op in
/// steady state (the one-time growth happens on the first use of the
/// owning API).
fn ensure_len(v: &mut Vec<f32>, len: usize) {
    if v.len() != len {
        v.resize(len, 0.0);
    }
}

/// Element count of the padded backward-data gradient.
fn gout_padded_len(p: &ConvParams) -> usize {
    p.n * p.k * (p.q() + 2 * (p.s - 1) * p.d)
}

/// The post-op context a plan hands its kernel for one fused forward
/// call: the epilogue spec, the plan's per-filter bias, and the optional
/// caller-supplied residual tensor (same shape as the output).
pub struct PostOpArgs<'a> {
    pub ops: &'a PostOps,
    pub bias: &'a [f32],
    pub residual: Option<&'a [f32]>,
}

/// A conv1d compute backend: the kernel contract behind a [`ConvPlan`].
///
/// Implementations are stateless unit structs registered in [`kernels`];
/// all mutable state lives in the plan's [`Workspace`], so one kernel
/// instance serves any number of concurrent plans. Kernels are selected
/// by **registry name** — adding one means implementing this trait and
/// appending a registry entry, never editing an enum:
///
/// ```
/// use dilconv1d::conv1d::{kernels, lookup_kernel};
///
/// let names: Vec<&str> = kernels().iter().map(|k| k.name()).collect();
/// assert_eq!(names, ["brgemm", "im2col", "direct", "bf16", "i8"]);
/// // Historical aliases resolve to their canonical kernels.
/// assert_eq!(lookup_kernel("onednn").unwrap().name(), "im2col");
/// assert!(lookup_kernel("cuda").is_none());
/// ```
pub trait ConvKernel: Send + Sync {
    /// Canonical registry name (round-trips through [`lookup_kernel`]).
    fn name(&self) -> &'static str;

    /// Storage precision of this kernel's forward pass. The plan derives
    /// its precision from this, and the autotuner only ranks kernels of
    /// the requested precision against each other — a reduced-precision
    /// kernel must never win an f32-keyed tuning entry.
    fn precision(&self) -> Precision {
        Precision::F32
    }

    /// Whether this kernel can run the given problem. All in-tree kernels
    /// are fully generic today; the hook exists so specialised kernels
    /// (ISA-gated, shape-restricted) can join the registry and the plan
    /// builder can reject or fall back cleanly.
    fn supports(&self, p: &ConvParams) -> bool {
        let _ = p;
        true
    }

    /// Workspace layout this kernel needs for `p` at the given worker
    /// count (excludes the plan-level `padded_in`/`gx_padded`/`out`
    /// buffers, which the plan grows lazily when their APIs are used).
    /// Grid-capable kernels size per-worker scratch for the larger of the
    /// two partitionings, so one workspace serves either.
    fn workspace_spec(&self, p: &ConvParams, threads: usize) -> WorkspaceSpec;

    /// Scratch bytes this kernel needs for `p` — the cuDNN-style
    /// workspace-size query.
    fn workspace_bytes(&self, p: &ConvParams, threads: usize) -> usize {
        self.workspace_spec(p, threads).bytes()
    }

    /// Forward pass `(N, C, W) → (N, K, Q)`, overwriting `out`. The
    /// [`ExecCtx`] carries the worker count, the batch-vs-grid work
    /// [`Partition`] and the resolved SIMD micro-kernel set; kernels
    /// without an inner grid (im2col, direct) may ignore the partition.
    fn forward(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        out: &mut [f32],
        ctx: ExecCtx,
    );

    /// Fused-epilogue forward: like [`ConvKernel::forward`] but with the
    /// post-ops applied inside the kernel's output-block loop, so a
    /// `bias + relu` forward is one pass over the output. The default
    /// implementation is the unfused fallback (kernel pass + reference
    /// sweep) so out-of-tree kernels stay correct; every in-tree kernel
    /// overrides it with the truly fused loop. Only ever invoked at
    /// stride 1 (the plan serves `stride > 1` by subsampling).
    #[allow(clippy::too_many_arguments)]
    fn forward_post(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        args: &PostOpArgs<'_>,
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        self.forward(p, w, ws, x, out, ctx);
        post::apply_reference(args.ops, args.bias, args.residual, out, p.n, p.k, p.q());
    }

    /// Data gradient `(N, K, Q) → (N, C, W)`, overwriting `gin`.
    fn backward_data(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        gin: &mut [f32],
        ctx: ExecCtx,
    );

    /// Weight gradient in `(K, C, S)` layout, overwriting `gw`.
    #[allow(clippy::too_many_arguments)]
    fn backward_weight(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        x: &[f32],
        gw: &mut [f32],
        ctx: ExecCtx,
    );
}

/// The paper's width-blocked BRGEMM kernels (Algorithms 2–4).
pub struct BrgemmKernel;

impl ConvKernel for BrgemmKernel {
    fn name(&self) -> &'static str {
        "brgemm"
    }

    fn workspace_spec(&self, p: &ConvParams, threads: usize) -> WorkspaceSpec {
        // Grid-capable: per-worker windows sized for whichever partition
        // needs more workers.
        let t = workers_grid(p, threads);
        WorkspaceSpec {
            b_offs: t * p.s,
            gout_padded: gout_padded_len(p),
            gw_partials: t * p.s * p.c * p.k,
            // Forward grid stages K lines, backward-data stages C.
            stage: t * p.k.max(p.c) * WIDTH_BLOCK,
            ..WorkspaceSpec::default()
        }
    }

    fn forward(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        forward_with_scratch(
            p,
            x,
            &w.skc,
            out,
            ctx,
            &ws.a_offs_fwd,
            &mut ws.b_offs,
            &mut ws.stage,
        );
    }

    fn forward_post(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        args: &PostOpArgs<'_>,
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        forward_post_with_scratch(
            p,
            x,
            &w.skc,
            out,
            ctx,
            &ws.a_offs_fwd,
            &mut ws.b_offs,
            &mut ws.stage,
            args.ops,
            args.bias,
            args.residual,
        );
    }

    fn backward_data(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        gin: &mut [f32],
        ctx: ExecCtx,
    ) {
        backward_data_with_scratch(
            p,
            gout,
            &w.sck_flip,
            gin,
            ctx,
            &ws.a_offs_bwd,
            &mut ws.b_offs,
            &mut ws.gout_padded,
            &mut ws.stage,
        );
    }

    fn backward_weight(
        &self,
        p: &ConvParams,
        _w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        x: &[f32],
        gw: &mut [f32],
        ctx: ExecCtx,
    ) {
        backward_weight_with_scratch(p, gout, x, gw, ctx, &mut ws.gw_partials);
    }
}

/// The im2col + GEMM library baseline (oneDNN-analog). Backward passes
/// share the BRGEMM machinery, exactly as the enum backend always did.
pub struct Im2colKernel;

impl ConvKernel for Im2colKernel {
    fn name(&self) -> &'static str {
        "im2col"
    }

    fn workspace_spec(&self, p: &ConvParams, threads: usize) -> WorkspaceSpec {
        // The patch matrices are per-image (batch workers); the shared
        // BRGEMM backward scratch is sized for either partition.
        let tb = workers_batch(p, threads);
        let tg = workers_grid(p, threads);
        WorkspaceSpec {
            b_offs: tg * p.s,
            col: tb * p.c * p.s * p.q(),
            gout_padded: gout_padded_len(p),
            gw_partials: tg * p.s * p.c * p.k,
            // Only the delegated BRGEMM backward-data grids (C lines).
            stage: tg * p.c * WIDTH_BLOCK,
            ..WorkspaceSpec::default()
        }
    }

    fn forward(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        forward_im2col_post_with_scratch(
            p,
            x,
            &w.kcs,
            out,
            ctx,
            &mut ws.col,
            &PostOps::none(),
            &[],
            None,
        );
    }

    fn forward_post(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        args: &PostOpArgs<'_>,
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        forward_im2col_post_with_scratch(
            p,
            x,
            &w.kcs,
            out,
            ctx,
            &mut ws.col,
            args.ops,
            args.bias,
            args.residual,
        );
    }

    fn backward_data(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        gin: &mut [f32],
        ctx: ExecCtx,
    ) {
        BrgemmKernel.backward_data(p, w, ws, gout, gin, ctx);
    }

    fn backward_weight(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        x: &[f32],
        gw: &mut [f32],
        ctx: ExecCtx,
    ) {
        BrgemmKernel.backward_weight(p, w, ws, gout, x, gw, ctx);
    }
}

/// Naive direct loops — correctness oracle / unoptimised floor. Needs no
/// scratch at all; ignores `threads`.
pub struct DirectKernel;

impl ConvKernel for DirectKernel {
    fn name(&self) -> &'static str {
        "direct"
    }

    fn workspace_spec(&self, _p: &ConvParams, _threads: usize) -> WorkspaceSpec {
        WorkspaceSpec::default()
    }

    fn forward(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        _ws: &mut Workspace,
        x: &[f32],
        out: &mut [f32],
        _ctx: ExecCtx,
    ) {
        forward_direct_post(p, x, &w.kcs, out, &PostOps::none(), &[], None);
    }

    fn forward_post(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        _ws: &mut Workspace,
        x: &[f32],
        args: &PostOpArgs<'_>,
        out: &mut [f32],
        _ctx: ExecCtx,
    ) {
        forward_direct_post(p, x, &w.kcs, out, args.ops, args.bias, args.residual);
    }

    fn backward_data(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        _ws: &mut Workspace,
        gout: &[f32],
        gin: &mut [f32],
        _ctx: ExecCtx,
    ) {
        backward_data_direct(p, gout, &w.kcs, gin);
    }

    fn backward_weight(
        &self,
        p: &ConvParams,
        _w: &PlanWeights,
        _ws: &mut Workspace,
        gout: &[f32],
        x: &[f32],
        gw: &mut [f32],
        _ctx: ExecCtx,
    ) {
        backward_weight_direct_into(p, gout, x, gw);
    }
}

/// BRGEMM with bf16 storage (`VDPBF16PS` semantics): the input is staged
/// to bf16 in the workspace, products accumulate in f32 and the f32
/// accumulator is stored, so the plan keeps a uniform f32 tensor
/// interface. Backward passes run the f32 BRGEMM kernels — gradients stay
/// full precision, which is what the paper's mixed-precision training
/// path needs (Sec. 4.3).
pub struct Bf16Kernel;

impl ConvKernel for Bf16Kernel {
    fn name(&self) -> &'static str {
        "bf16"
    }

    fn precision(&self) -> Precision {
        Precision::Bf16
    }

    fn workspace_spec(&self, p: &ConvParams, threads: usize) -> WorkspaceSpec {
        let t = workers_grid(p, threads);
        WorkspaceSpec {
            b_offs: t * p.s,
            gout_padded: gout_padded_len(p),
            gw_partials: t * p.s * p.c * p.k,
            stage: t * p.k.max(p.c) * WIDTH_BLOCK,
            xb: p.n * p.c * p.w,
            ..WorkspaceSpec::default()
        }
    }

    fn forward(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        to_bf16_into(x, &mut ws.xb);
        forward_bf16_f32out_post_with_scratch(
            p,
            &ws.xb,
            &w.skc_bf16,
            out,
            ctx,
            &ws.a_offs_fwd,
            &mut ws.b_offs,
            &mut ws.stage,
            &PostOps::none(),
            &[],
            None,
        );
    }

    fn forward_post(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        args: &PostOpArgs<'_>,
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        to_bf16_into(x, &mut ws.xb);
        forward_bf16_f32out_post_with_scratch(
            p,
            &ws.xb,
            &w.skc_bf16,
            out,
            ctx,
            &ws.a_offs_fwd,
            &mut ws.b_offs,
            &mut ws.stage,
            args.ops,
            args.bias,
            args.residual,
        );
    }

    fn backward_data(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        gin: &mut [f32],
        ctx: ExecCtx,
    ) {
        BrgemmKernel.backward_data(p, w, ws, gout, gin, ctx);
    }

    fn backward_weight(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        x: &[f32],
        gw: &mut [f32],
        ctx: ExecCtx,
    ) {
        BrgemmKernel.backward_weight(p, w, ws, gout, x, gw, ctx);
    }
}

/// BRGEMM with int8 per-channel symmetric quantized storage (VNNI-style
/// i32-accumulate semantics): the input is quantized into the workspace
/// with the plan's calibrated per-tensor activation scale, the weight is
/// quantized per output channel at layout-derivation time, the integer
/// BRGEMM accumulates **exactly** in i32 and each accumulator row is
/// dequantized with `deq[k] = scale_x · scale_w[k]` before the f32
/// post-op epilogue — the requantize-at-the-fusion-boundary contract.
/// Exact integer accumulation makes every ISA level, partitioning and
/// thread count bit-identical by construction. Inference-only numerics:
/// backward passes run the f32 BRGEMM kernels on the full-precision
/// layouts the plan keeps alongside.
pub struct I8Kernel;

impl ConvKernel for I8Kernel {
    fn name(&self) -> &'static str {
        "i8"
    }

    fn precision(&self) -> Precision {
        Precision::I8
    }

    fn workspace_spec(&self, p: &ConvParams, threads: usize) -> WorkspaceSpec {
        let t = workers_grid(p, threads);
        WorkspaceSpec {
            b_offs: t * p.s,
            gout_padded: gout_padded_len(p),
            gw_partials: t * p.s * p.c * p.k,
            // Only the delegated f32 BRGEMM backward-data grids (C lines);
            // the i8 forward stages in `iacc` instead.
            stage: t * p.c * WIDTH_BLOCK,
            xq: p.n * p.c * p.w,
            iacc: t * 2 * p.k * WIDTH_BLOCK,
            ..WorkspaceSpec::default()
        }
    }

    fn forward(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        let args = PostOpArgs {
            ops: &PostOps::none(),
            bias: &[],
            residual: None,
        };
        self.forward_post(p, w, ws, x, &args, out, ctx);
    }

    fn forward_post(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        x: &[f32],
        args: &PostOpArgs<'_>,
        out: &mut [f32],
        ctx: ExecCtx,
    ) {
        quant::quantize_into(x, w.input_scale, &mut ws.xq);
        forward_i8_f32out_post_with_scratch(
            p,
            &ws.xq,
            &w.skc_i8,
            &w.deq,
            out,
            ctx,
            &ws.a_offs_fwd,
            &mut ws.b_offs,
            &mut ws.iacc,
            args.ops,
            args.bias,
            args.residual,
        );
    }

    fn backward_data(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        gin: &mut [f32],
        ctx: ExecCtx,
    ) {
        BrgemmKernel.backward_data(p, w, ws, gout, gin, ctx);
    }

    fn backward_weight(
        &self,
        p: &ConvParams,
        w: &PlanWeights,
        ws: &mut Workspace,
        gout: &[f32],
        x: &[f32],
        gw: &mut [f32],
        ctx: ExecCtx,
    ) {
        BrgemmKernel.backward_weight(p, w, ws, gout, x, gw, ctx);
    }
}

/// The backend registry: every kernel the plan builder can select.
static KERNELS: [&(dyn ConvKernel); 5] =
    [&BrgemmKernel, &Im2colKernel, &DirectKernel, &Bf16Kernel, &I8Kernel];

/// All registered kernels, in preference order.
pub fn kernels() -> &'static [&'static dyn ConvKernel] {
    &KERNELS
}

/// Look a kernel up by name. Accepts the same aliases as
/// `Backend::from_str` plus `"bf16"`/`"bfloat16"` and `"i8"`/`"int8"` —
/// configs and benches select backends by string without touching the
/// enum.
pub fn lookup_kernel(name: &str) -> Option<&'static dyn ConvKernel> {
    let canonical = match name.to_ascii_lowercase().as_str() {
        "brgemm" | "libxsmm" | "ours" => "brgemm",
        "im2col" | "onednn" | "baseline" => "im2col",
        "direct" | "naive" => "direct",
        "bf16" | "bfloat16" => "bf16",
        "i8" | "int8" => "i8",
        _ => return None,
    };
    kernels().iter().copied().find(|k| k.name() == canonical)
}

/// How a [`PlanOptions`] build selects its kernel.
#[derive(Clone)]
enum KernelSel {
    /// Enum backend + requested precision (the [`ConvPlan::new`] rule:
    /// bf16/i8 require the BRGEMM backend).
    Backend(Backend),
    /// Registry name / alias; the kernel's own precision wins.
    Name(String),
    /// Let the in-process autotuner pick.
    Tuned,
    /// Explicit kernel instance (registry or caller-owned).
    Explicit(&'static dyn ConvKernel),
}

/// Everything configurable about a plan, gathered into one builder —
/// the single entry [`ConvPlan::build`] takes instead of the historical
/// constructor/setter sprawl (`new` / `by_name` / `tuned` /
/// `with_partition` / `with_inference` / `with_post_ops`, all of which
/// now delegate here).
///
/// ```
/// use dilconv1d::conv1d::{ConvParams, ConvPlan, Partition, PlanOptions};
///
/// let p = ConvParams::new(1, 2, 3, 32, 5, 2).unwrap();
/// let plan = ConvPlan::build(
///     p,
///     vec![0.1f32; 3 * 2 * 5],
///     PlanOptions::new()
///         .backend_name("brgemm")
///         .threads(2)
///         .partition(Partition::Grid)
///         .inference(true),
/// )
/// .unwrap();
/// assert_eq!(plan.kernel_name(), "brgemm");
/// assert!(plan.is_inference());
/// ```
#[derive(Clone)]
pub struct PlanOptions {
    kernel: KernelSel,
    precision: Precision,
    threads: usize,
    partition: Partition,
    inference: bool,
    post: PostOps,
    placement: Option<Placement>,
}

impl Default for PlanOptions {
    /// Single-threaded f32 BRGEMM, batch partition, trainable, no
    /// post-ops, flat placement.
    fn default() -> PlanOptions {
        PlanOptions {
            kernel: KernelSel::Backend(Backend::Brgemm),
            precision: Precision::F32,
            threads: 1,
            partition: Partition::Batch,
            inference: false,
            post: PostOps::none(),
            placement: None,
        }
    }
}

impl std::fmt::Debug for PlanOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kernel = match &self.kernel {
            KernelSel::Backend(b) => b.as_str(),
            KernelSel::Name(n) => n.as_str(),
            KernelSel::Tuned => "<tuned>",
            KernelSel::Explicit(k) => k.name(),
        };
        f.debug_struct("PlanOptions")
            .field("kernel", &kernel)
            .field("precision", &self.precision)
            .field("threads", &self.threads)
            .field("partition", &self.partition)
            .field("inference", &self.inference)
            .finish()
    }
}

impl PlanOptions {
    pub fn new() -> PlanOptions {
        PlanOptions::default()
    }

    /// Select by enum backend; combined with [`Self::precision`] exactly
    /// as [`ConvPlan::new`] always did (bf16/i8 need BRGEMM).
    pub fn backend(mut self, backend: Backend) -> PlanOptions {
        self.kernel = KernelSel::Backend(backend);
        self
    }

    /// Select by registry name or alias (`"brgemm"`, `"onednn"`, …);
    /// the named kernel's own precision wins.
    pub fn backend_name(mut self, name: impl Into<String>) -> PlanOptions {
        self.kernel = KernelSel::Name(name.into());
        self
    }

    /// Let the in-process autotuner choose the kernel (the
    /// [`ConvPlan::tuned`] path): the first call for a shape
    /// micro-benchmarks the candidates under the requested partition,
    /// later calls reuse the memoized winner.
    pub fn tuned(mut self) -> PlanOptions {
        self.kernel = KernelSel::Tuned;
        self
    }

    /// Select an explicit kernel instance (registry or caller-owned).
    pub fn kernel(mut self, kernel: &'static dyn ConvKernel) -> PlanOptions {
        self.kernel = KernelSel::Explicit(kernel);
        self
    }

    /// Forward-pass storage precision (with [`Self::backend`] /
    /// [`Self::tuned`] selection).
    pub fn precision(mut self, precision: Precision) -> PlanOptions {
        self.precision = precision;
        self
    }

    /// Worker threads the workspace is sized for.
    pub fn threads(mut self, threads: usize) -> PlanOptions {
        self.threads = threads;
        self
    }

    /// Batch vs 2D-grid work splitting.
    pub fn partition(mut self, partition: Partition) -> PlanOptions {
        self.partition = partition;
        self
    }

    /// Forward-only plan: backward scratch is never allocated and
    /// `execute_backward_*` panics (the serving path).
    pub fn inference(mut self, inference: bool) -> PlanOptions {
        self.inference = inference;
        self
    }

    /// Post-op epilogue fused into the forward/backward passes.
    pub fn post_ops(mut self, ops: PostOps) -> PlanOptions {
        self.post = ops;
        self
    }

    /// Thread→socket layout carried in the plan's [`ExecCtx`] (flat over
    /// `threads` unless set).
    pub fn placement(mut self, placement: Placement) -> PlanOptions {
        self.placement = Some(placement);
        self
    }
}

/// A fully-prepared convolution: kernel choice, derived weight layouts,
/// padding geometry and workspace, built once and executed many times.
///
/// ```
/// use dilconv1d::conv1d::{ConvParams, ConvPlan};
///
/// // N=1, C=2, K=3, W=32, S=5, d=2  →  Q = 32 − (5−1)·2 = 24.
/// let p = ConvParams::new(1, 2, 3, 32, 5, 2).unwrap();
/// let weights = vec![0.1f32; 3 * 2 * 5]; // (K, C, S)
/// let mut plan = ConvPlan::by_name(p, "brgemm", 1, weights).unwrap();
///
/// let x = vec![1.0f32; 2 * 32];
/// let mut out = vec![0.0f32; 3 * 24];
/// plan.execute_forward_into(&x, &mut out); // steady state: 0 allocations
/// assert_eq!(plan.params().q(), 24);
/// // Every output sums C·S = 10 taps of 0.1 × 1.0.
/// assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-5));
/// ```
pub struct ConvPlan {
    p: ConvParams,
    /// Stride-1 twin of `p` — the geometry the kernels compute; equals
    /// `p` for stride-1 plans. `stride > 1` is served by subsampling the
    /// stride-1 output inside the (fused) epilogue pass.
    kp: ConvParams,
    kernel: &'static dyn ConvKernel,
    precision: Precision,
    threads: usize,
    /// Batch vs 2D-grid work splitting the kernels run under.
    partition: Partition,
    /// SIMD micro-kernel set resolved once at construction (the
    /// process-active ISA; `CONV1D_FORCE_ISA` override honoured).
    uks: &'static MicroKernelSet,
    /// `(left, right)` same-padding for this `(S, d)`.
    pad: (usize, usize),
    weights: PlanWeights,
    bias: Vec<f32>,
    /// Post-op epilogue executed by the fused forward/backward paths.
    post: PostOps,
    /// Forward-only plan: backward scratch was never allocated and the
    /// `execute_backward_*` family panics (the serving path, DESIGN.md
    /// §7 — a silent backward on a trimmed workspace would be a bug).
    inference: bool,
    /// Thread→socket layout carried in the [`ExecCtx`] (flat unless a
    /// NUMA-aware caller placed the workers via [`PlanOptions::placement`]).
    placement: Placement,
    /// Whether `ws.padded_in` holds a valid input from
    /// `execute_forward_same_into` (guards the cached backward-weight).
    same_cached: bool,
    ws: Workspace,
}

impl std::fmt::Debug for ConvPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ConvPlan")
            .field("params", &self.p)
            .field("kernel", &self.kernel.name())
            .field("precision", &self.precision)
            .field("threads", &self.threads)
            .field("partition", &self.partition)
            .field("isa", &self.uks.isa())
            .field("workspace_bytes", &self.ws.bytes())
            .finish()
    }
}

impl ConvPlan {
    /// Build a plan from a [`PlanOptions`] bundle — the one constructor
    /// every historical entry point delegates to.
    pub fn build(p: ConvParams, w_kcs: Vec<f32>, opts: PlanOptions) -> Result<ConvPlan, PlanError> {
        let kernel: &'static dyn ConvKernel = match &opts.kernel {
            KernelSel::Backend(backend) => {
                let name = match (*backend, opts.precision) {
                    (Backend::Brgemm, Precision::Bf16) => "bf16",
                    (Backend::Brgemm, Precision::I8) => "i8",
                    (b, Precision::Bf16) => {
                        return Err(PlanError(format!(
                            "precision bf16 requires the brgemm backend, got {b}"
                        )))
                    }
                    (b, Precision::I8) => {
                        return Err(PlanError(format!(
                            "precision i8 requires the brgemm backend, got {b}"
                        )))
                    }
                    (b, Precision::F32) => b.as_str(),
                };
                lookup_kernel(name).ok_or_else(|| PlanError(format!("unknown kernel '{name}'")))?
            }
            KernelSel::Name(name) => lookup_kernel(name)
                .ok_or_else(|| PlanError(format!("unknown kernel '{name}'")))?,
            KernelSel::Tuned => {
                super::tune::autotuner().choose(&p, opts.threads, opts.precision, opts.partition)
            }
            KernelSel::Explicit(k) => *k,
        };
        let mut plan = Self::with_kernel(p, kernel, opts.threads, w_kcs)?;
        plan.partition = opts.partition;
        plan.post = opts.post;
        if let Some(placement) = opts.placement {
            plan.placement = placement;
        }
        if opts.inference {
            plan = plan.with_inference();
        }
        Ok(plan)
    }

    /// Build a plan from a problem descriptor, an enum backend and a
    /// precision. `Precision::Bf16` is served by the bf16 kernel and
    /// `Precision::I8` by the int8 kernel; both are only available on the
    /// BRGEMM backend (as in the paper). Thin wrapper over
    /// [`Self::build`].
    pub fn new(
        p: ConvParams,
        backend: Backend,
        precision: Precision,
        threads: usize,
        w_kcs: Vec<f32>,
    ) -> Result<ConvPlan, PlanError> {
        Self::build(
            p,
            w_kcs,
            PlanOptions::new()
                .backend(backend)
                .precision(precision)
                .threads(threads),
        )
    }

    /// Build a plan from a registry kernel name (`"brgemm"`, `"im2col"`,
    /// `"direct"`, `"bf16"` or any `Backend::from_str` alias). Thin
    /// wrapper over [`Self::build`].
    pub fn by_name(
        p: ConvParams,
        kernel: &str,
        threads: usize,
        w_kcs: Vec<f32>,
    ) -> Result<ConvPlan, PlanError> {
        Self::build(
            p,
            w_kcs,
            PlanOptions::new().backend_name(kernel).threads(threads),
        )
    }

    /// Build a plan whose kernel is chosen by the in-process autotuner
    /// ([`super::tune::autotuner`]): the first call for a shape
    /// micro-benchmarks the candidates (under the requested partition —
    /// grid rankings differ from batch ones at N < threads), later calls
    /// reuse the memoized winner. The returned plan already runs under
    /// `partition`. Thin wrapper over [`Self::build`].
    pub fn tuned(
        p: ConvParams,
        precision: Precision,
        threads: usize,
        partition: Partition,
        w_kcs: Vec<f32>,
    ) -> Result<ConvPlan, PlanError> {
        Self::build(
            p,
            w_kcs,
            PlanOptions::new()
                .tuned()
                .precision(precision)
                .threads(threads)
                .partition(partition),
        )
    }

    /// Build a plan for an explicit kernel (registry or caller-owned).
    pub fn with_kernel(
        p: ConvParams,
        kernel: &'static dyn ConvKernel,
        threads: usize,
        w_kcs: Vec<f32>,
    ) -> Result<ConvPlan, PlanError> {
        if w_kcs.len() != p.k * p.c * p.s {
            return Err(PlanError(format!(
                "weight length {} does not match (K,C,S)=({},{},{})",
                w_kcs.len(),
                p.k,
                p.c,
                p.s
            )));
        }
        // Kernels compute at stride 1; capability, workspace and offset
        // tables are all judged against the stride-1 twin the kernel
        // will actually execute (the plan subsamples the output).
        let kp = p.unit_stride();
        if !kernel.supports(&kp) {
            return Err(PlanError(format!(
                "kernel '{}' does not support {kp}",
                kernel.name()
            )));
        }
        let threads = threads.max(1);
        let precision = kernel.precision();
        // The plan-level padded_in / gx_padded / out buffers are grown
        // lazily by the same-padding and owned-output APIs — `_into`-only
        // callers (benches, sweeps) never pay for them.
        let spec = kernel.workspace_spec(&kp, threads);
        let ws = Workspace::from_spec(&kp, &spec);
        let mut weights = PlanWeights {
            skc: vec![0.0; w_kcs.len()],
            sck_flip: vec![0.0; w_kcs.len()],
            skc_bf16: Vec::new(),
            skc_i8: Vec::new(),
            w_scales: Vec::new(),
            deq: Vec::new(),
            input_scale: 1.0,
            kcs: w_kcs,
        };
        derive_layouts(&p, &mut weights, precision);
        Ok(ConvPlan {
            pad: ConvParams::same_pad(p.s, p.d),
            p,
            kp,
            kernel,
            precision,
            threads,
            partition: Partition::Batch,
            uks: simd::active(),
            weights,
            bias: Vec::new(),
            post: PostOps::none(),
            inference: false,
            placement: Placement::flat(threads),
            same_cached: false,
            ws,
        })
    }

    /// Builder: make this a **forward-only** plan. The backward scratch
    /// (`gout_padded`, the per-worker `gw_partials`) is released — for
    /// the 25-layer serving network this is most of a plan's resident
    /// footprint — and every `execute_backward_*` call panics instead of
    /// running against missing buffers. The serving plan cache builds
    /// its per-bucket plans this way (DESIGN.md §7).
    pub fn with_inference(mut self) -> ConvPlan {
        if !self.inference {
            self.inference = true;
            let mut spec = self.kernel.workspace_spec(&self.kp, self.threads);
            spec.gout_padded = 0;
            spec.gw_partials = 0;
            self.ws = Workspace::from_spec(&self.kp, &spec);
        }
        self
    }

    /// True for forward-only plans built via [`Self::with_inference`].
    pub fn is_inference(&self) -> bool {
        self.inference
    }

    fn assert_trainable(&self, pass: &str) {
        assert!(
            !self.inference,
            "{pass} on an inference-only plan for {} (build without with_inference() to train)",
            self.p
        );
    }

    /// The execution context the kernels run under.
    fn ctx(&self) -> ExecCtx {
        ExecCtx {
            threads: self.threads,
            partition: self.partition,
            uks: self.uks,
            placement: self.placement,
        }
    }

    /// The problem this plan was built for.
    pub fn params(&self) -> &ConvParams {
        &self.p
    }

    /// Canonical name of the kernel behind this plan.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// Precision of the forward pass.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Worker count the workspace was sized for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Work-partitioning strategy the kernels run under.
    pub fn partition(&self) -> Partition {
        self.partition
    }

    /// Thread→socket layout the kernels' [`ExecCtx`] carries.
    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Builder: select the work partitioning at construction time.
    /// [`Partition::Grid`] splits the `N × ceil(Q/64)` width-block grid,
    /// so a single long-sequence image uses every worker.
    pub fn with_partition(mut self, partition: Partition) -> ConvPlan {
        self.partition = partition;
        self
    }

    /// Replace the work-partitioning strategy (the workspace is sized for
    /// either, so no rebuild is needed). Results are bit-identical across
    /// partitionings for the forward and backward-data passes.
    pub fn set_partition(&mut self, partition: Partition) {
        self.partition = partition;
    }

    /// ISA level of the SIMD micro-kernels this plan dispatches to
    /// (resolved once at construction; `CONV1D_FORCE_ISA` honoured).
    pub fn isa(&self) -> Isa {
        self.uks.isa()
    }

    /// Bytes of workspace this plan holds — the cuDNN-style query, now
    /// answering for the concrete allocation.
    pub fn workspace_bytes(&self) -> usize {
        self.ws.bytes()
    }

    /// `(left, right)` same-padding geometry for this plan's `(S, d)`.
    pub fn same_pad(&self) -> (usize, usize) {
        self.pad
    }

    /// Input width *before* same-padding (`W − left − right`).
    pub fn unpadded_width(&self) -> usize {
        self.p.w - self.pad.0 - self.pad.1
    }

    /// True when this plan can serve a `(n, w)` problem under the given
    /// backend/precision/threads without rebuilding.
    pub fn matches(
        &self,
        p: &ConvParams,
        backend: Backend,
        precision: Precision,
        threads: usize,
    ) -> bool {
        let name = match (backend, precision) {
            (Backend::Brgemm, Precision::Bf16) => "bf16",
            (Backend::Brgemm, Precision::I8) => "i8",
            (_, Precision::Bf16 | Precision::I8) => return false,
            (b, Precision::F32) => b.as_str(),
        };
        self.p == *p && self.kernel.name() == name && self.threads == threads.max(1)
    }

    /// Replace the weights (same shape) and refresh every derived layout
    /// in place — zero allocations.
    pub fn set_weights(&mut self, w_kcs: &[f32]) {
        assert_eq!(
            w_kcs.len(),
            self.p.k * self.p.c * self.p.s,
            "weight shape mismatch for {}",
            self.p
        );
        self.weights.kcs.copy_from_slice(w_kcs);
        derive_layouts(&self.p, &mut self.weights, self.precision);
    }

    /// Framework-layout weights `(K, C, S)`.
    pub fn weights(&self) -> &[f32] {
        &self.weights.kcs
    }

    /// Install a calibrated per-tensor activation scale (absmax/127 over
    /// a warm-up batch, [`super::quant::scale_from_absmax`]). Only the
    /// combined dequantization scales are refreshed, so repeated calls
    /// with an unchanged scale are free. A no-op in effect for non-i8
    /// plans (their `deq` table is empty).
    pub fn set_input_scale(&mut self, scale: f32) {
        assert!(
            scale.is_finite() && scale > 0.0,
            "input scale must be positive and finite, got {scale}"
        );
        if self.weights.input_scale != scale {
            self.weights.input_scale = scale;
            for (d, &ws) in self.weights.deq.iter_mut().zip(&self.weights.w_scales) {
                *d = scale * ws;
            }
        }
    }

    /// The per-tensor activation scale the i8 forward quantizes with.
    pub fn input_scale(&self) -> f32 {
        self.weights.input_scale
    }

    /// Set the per-filter bias added by the same-padding forward and the
    /// fused post-op pipeline.
    pub fn set_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.p.k, "bias length mismatch");
        if self.bias.len() != self.p.k {
            self.bias = bias.to_vec();
        } else {
            self.bias.copy_from_slice(bias);
        }
    }

    /// Builder: attach a post-op epilogue spec at construction time.
    pub fn with_post_ops(mut self, ops: PostOps) -> ConvPlan {
        self.post = ops;
        self
    }

    /// Replace the post-op epilogue spec.
    pub fn set_post_ops(&mut self, ops: PostOps) {
        self.post = ops;
    }

    /// The post-op epilogue this plan fuses into
    /// [`Self::execute_forward_post_into`] and the fused backward.
    pub fn post_ops(&self) -> &PostOps {
        &self.post
    }

    /// Forward over a pre-padded `(N, C, W)` input into a caller-owned
    /// `(N, K, Q)` buffer — raw convolution, no post-ops. Zero heap
    /// allocations in steady state.
    pub fn execute_forward_into(&mut self, x: &[f32], out: &mut [f32]) {
        self.forward_dispatch(x, None, out, &PostOps::none());
    }

    /// Fused-epilogue forward: applies this plan's [`PostOps`] (scale,
    /// bias, residual add, activation) **inside** the kernel's output
    /// block loop — one pass over the output tensor instead of separate
    /// bias/activation sweeps. `residual` must be `Some` (shape
    /// `(N, K, Q)`) iff the spec has `residual` set. Zero heap
    /// allocations in steady state.
    pub fn execute_forward_post_into(
        &mut self,
        x: &[f32],
        residual: Option<&[f32]>,
        out: &mut [f32],
    ) {
        let post = self.post;
        self.forward_dispatch(x, residual, out, &post);
    }

    fn forward_dispatch(
        &mut self,
        x: &[f32],
        residual: Option<&[f32]>,
        out: &mut [f32],
        ops: &PostOps,
    ) {
        let (n, c, k, w, q) = (self.p.n, self.p.c, self.p.k, self.p.w, self.p.q());
        assert_eq!(x.len(), n * c * w, "input shape mismatch for {}", self.p);
        assert_eq!(out.len(), n * k * q, "output shape mismatch for {}", self.p);
        if ops.bias {
            assert_eq!(
                self.bias.len(),
                k,
                "bias post-op without a plan bias (call set_bias) for {}",
                self.p
            );
        }
        let res = residual.filter(|_| ops.residual);
        if ops.residual {
            let r = res.expect("residual post-op requires a residual tensor");
            assert_eq!(r.len(), n * k * q, "residual shape mismatch for {}", self.p);
        }
        let ctx = self.ctx();
        if self.p.stride == 1 {
            let args = PostOpArgs {
                ops,
                bias: &self.bias,
                residual: res,
            };
            self.kernel
                .forward_post(&self.kp, &self.weights, &mut self.ws, x, &args, out, ctx);
            return;
        }
        // stride > 1: the kernel computes the stride-1 output into the
        // staging buffer; one epilogue pass (still fused with the
        // post-ops) subsamples it into `out`.
        let q1 = self.kp.q();
        let stride = self.p.stride;
        let mut full = std::mem::take(&mut self.ws.full);
        ensure_len(&mut full, n * k * q1);
        self.kernel
            .forward(&self.kp, &self.weights, &mut self.ws, x, &mut full, ctx);
        for row in 0..n * k {
            let full_row = &full[row * q1..(row + 1) * q1];
            let out_row = &mut out[row * q..(row + 1) * q];
            for (j, o) in out_row.iter_mut().enumerate() {
                *o = full_row[j * stride];
            }
            // The epilogue math lives in post::apply_segment only, so the
            // strided path can never drift from the fused kernels'.
            if !ops.is_none() {
                let bias_k = if ops.bias { self.bias[row % k] } else { 0.0 };
                let res_seg = res.map(|r| &r[row * q..(row + 1) * q]);
                post::apply_segment(ops, bias_k, res_seg, out_row);
            }
        }
        self.ws.full = full;
    }

    /// Forward into the plan's owned output buffer; returns it as a
    /// slice. Zero heap allocations in steady state (the buffer is grown
    /// once on first use).
    pub fn execute_forward(&mut self, x: &[f32]) -> &[f32] {
        let mut out = std::mem::take(&mut self.ws.out);
        ensure_len(&mut out, self.p.n * self.p.k * self.p.q());
        self.execute_forward_into(x, &mut out);
        self.ws.out = out;
        &self.ws.out
    }

    /// Same-padding forward: pads an unpadded `(N, C, W−pad)` input into
    /// the workspace, runs the kernel and adds the per-filter bias.
    /// `out` is `(N, K, W−pad)`. The padded input stays cached in the
    /// workspace for [`Self::execute_backward_weight_cached_into`].
    pub fn execute_forward_same_into(&mut self, x: &[f32], out: &mut [f32]) {
        let (n, c, k) = (self.p.n, self.p.c, self.p.k);
        assert_eq!(self.p.stride, 1, "same-padding requires stride 1");
        let wu = self.unpadded_width();
        assert_eq!(
            self.p.q(),
            wu,
            "plan was not built with same-padding geometry ({})",
            self.p
        );
        assert_eq!(x.len(), n * c * wu, "input shape mismatch for {}", self.p);
        assert_eq!(out.len(), n * k * wu, "output shape mismatch for {}", self.p);
        ensure_len(&mut self.ws.padded_in, n * c * self.p.w);
        pad_width_into(x, n, c, wu, self.pad.0, self.pad.1, &mut self.ws.padded_in);
        let xp = std::mem::take(&mut self.ws.padded_in);
        let ctx = self.ctx();
        self.kernel
            .forward(&self.p, &self.weights, &mut self.ws, &xp, out, ctx);
        self.ws.padded_in = xp;
        self.same_cached = true;
        if !self.bias.is_empty() {
            for ib in 0..n {
                for ik in 0..k {
                    let b = self.bias[ik];
                    if b != 0.0 {
                        let row = (ib * k + ik) * wu;
                        for v in &mut out[row..row + wu] {
                            *v += b;
                        }
                    }
                }
            }
        }
    }

    /// Data gradient `(N, K, Q) → (N, C, W)` into a caller-owned buffer.
    /// Zero heap allocations in steady state.
    pub fn execute_backward_data_into(&mut self, gout: &[f32], gin: &mut [f32]) {
        let (n, c, k, w, q) = (self.p.n, self.p.c, self.p.k, self.p.w, self.p.q());
        assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch for {}", self.p);
        assert_eq!(gin.len(), n * c * w, "grad-in shape mismatch for {}", self.p);
        self.execute_backward_data_into_raw(gout, gin);
    }

    /// Scatter a strided `(N, K, Q)` output-domain tensor onto the
    /// stride-1 grid `(N, K, Q₁)` (zeros between the strided positions) —
    /// the adjoint of the forward subsampling.
    fn scatter_to_unit_stride(&self, gout: &[f32], full: &mut Vec<f32>) {
        let (n, k, q) = (self.p.n, self.p.k, self.p.q());
        let (q1, stride) = (self.kp.q(), self.p.stride);
        ensure_len(full, n * k * q1);
        for (full_row, gout_row) in full.chunks_mut(q1).zip(gout.chunks(q)) {
            for (j1, v) in full_row.iter_mut().enumerate() {
                *v = if j1 % stride == 0 && j1 / stride < q {
                    gout_row[j1 / stride]
                } else {
                    0.0
                };
            }
        }
    }

    /// Same-padding data gradient: computes the padded `(N, C, W)` data
    /// gradient in the workspace and strips the pad columns into the
    /// caller's `(N, C, W−pad)` buffer.
    pub fn execute_backward_data_same_into(&mut self, gout: &[f32], gx: &mut [f32]) {
        let (n, c, w) = (self.p.n, self.p.c, self.p.w);
        let wu = self.unpadded_width();
        assert_eq!(gx.len(), n * c * wu, "grad shape mismatch for {}", self.p);
        let mut gxp = std::mem::take(&mut self.ws.gx_padded);
        ensure_len(&mut gxp, n * c * w);
        self.execute_backward_data_into(gout, &mut gxp);
        unpad_width_into(&gxp, n, c, w, self.pad.0, self.pad.1, gx);
        self.ws.gx_padded = gxp;
    }

    /// Weight gradient in `(K, C, S)` layout into a caller-owned buffer.
    /// `x` is the (pre-padded) forward input. Zero heap allocations in
    /// steady state.
    pub fn execute_backward_weight_into(&mut self, gout: &[f32], x: &[f32], gw: &mut [f32]) {
        let (n, c, k, s, w, q) = (
            self.p.n,
            self.p.c,
            self.p.k,
            self.p.s,
            self.p.w,
            self.p.q(),
        );
        assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch for {}", self.p);
        assert_eq!(x.len(), n * c * w, "input shape mismatch for {}", self.p);
        assert_eq!(gw.len(), k * c * s, "grad-weight shape mismatch for {}", self.p);
        self.execute_backward_weight_into_raw(gout, x, gw);
    }

    /// Fused backward through the post-op pipeline — the adjoint of
    /// [`Self::execute_forward_post_into`]. A single prologue sweep turns
    /// `gout` (the gradient w.r.t. the post-op output) into the
    /// activation-masked, scaled convolution gradient, folding the bias
    /// gradient and the residual gradient into that same sweep; the
    /// kernel backward passes then consume it directly — no separate
    /// mask/bias sweeps over the gradient tensor.
    ///
    /// * `y` — the **saved forward output**: activation gradients are
    ///   reconstructed from it (`relu': y > 0`, `sigmoid': y·(1−y)`), so
    ///   no pre-activation tensor is ever materialised;
    /// * `x` — the forward input `(N, C, W)` (pre-padded);
    /// * `gin` `(N, C, W)`, `gb` (`K`, overwritten) and `gres`
    ///   `(N, K, Q)` are filled when `Some`; `gw` `(K, C, S)` always.
    ///   A requested `gb`/`gres` whose op is **absent from the spec** is
    ///   zeroed — a parameter that never entered the forward has zero
    ///   gradient.
    ///
    /// Zero heap allocations in steady state (the prologue buffer is
    /// grown once on first use).
    #[allow(clippy::too_many_arguments)]
    pub fn execute_backward_fused_into(
        &mut self,
        gout: &[f32],
        y: &[f32],
        x: &[f32],
        gin: Option<&mut [f32]>,
        gw: &mut [f32],
        mut gb: Option<&mut [f32]>,
        mut gres: Option<&mut [f32]>,
    ) {
        let (n, c, k, s, w, q) = (
            self.p.n,
            self.p.c,
            self.p.k,
            self.p.s,
            self.p.w,
            self.p.q(),
        );
        assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch for {}", self.p);
        assert_eq!(y.len(), n * k * q, "saved-output shape mismatch for {}", self.p);
        assert_eq!(x.len(), n * c * w, "input shape mismatch for {}", self.p);
        assert_eq!(gw.len(), k * c * s, "grad-weight shape mismatch for {}", self.p);
        if let Some(gin) = gin.as_deref() {
            assert_eq!(gin.len(), n * c * w, "grad-in shape mismatch for {}", self.p);
        }
        if let Some(gb) = gb.as_deref() {
            assert_eq!(gb.len(), k, "bias-grad length mismatch for {}", self.p);
        }
        if let Some(gr) = gres.as_deref() {
            assert_eq!(gr.len(), n * k * q, "residual-grad shape mismatch for {}", self.p);
        }
        self.assert_trainable("execute_backward_fused_into");
        if let Some(gb) = gb.as_deref_mut() {
            gb.fill(0.0);
        }
        let post = self.post;
        // Ops absent from the spec did not participate in the forward, so
        // their gradients are zero — don't let the prologue fill them.
        if !post.residual {
            if let Some(gr) = gres.as_deref_mut() {
                gr.fill(0.0);
            }
        }
        let ctx = self.ctx();
        let mut gpre = std::mem::take(&mut self.ws.gpre);
        ensure_len(&mut gpre, n * k * q);
        post::backward_prologue(
            &post,
            gout,
            y,
            &mut gpre,
            n,
            k,
            q,
            if post.bias { gb } else { None },
            if post.residual { gres } else { None },
        );
        if self.p.stride == 1 {
            if let Some(gin) = gin {
                self.kernel.backward_data(
                    &self.kp,
                    &self.weights,
                    &mut self.ws,
                    &gpre,
                    gin,
                    ctx,
                );
            }
            self.kernel.backward_weight(
                &self.kp,
                &self.weights,
                &mut self.ws,
                &gpre,
                x,
                gw,
                ctx,
            );
        } else {
            // One scatter onto the stride-1 grid serves both kernel
            // backward passes.
            let mut full = std::mem::take(&mut self.ws.full);
            self.scatter_to_unit_stride(&gpre, &mut full);
            if let Some(gin) = gin {
                self.kernel.backward_data(
                    &self.kp,
                    &self.weights,
                    &mut self.ws,
                    &full,
                    gin,
                    ctx,
                );
            }
            self.kernel.backward_weight(
                &self.kp,
                &self.weights,
                &mut self.ws,
                &full,
                x,
                gw,
                ctx,
            );
            self.ws.full = full;
        }
        self.ws.gpre = gpre;
    }

    /// Backward-data on an already-prologued gradient (no shape asserts
    /// beyond the dispatch; shared by the raw and fused paths).
    fn execute_backward_data_into_raw(&mut self, gpre: &[f32], gin: &mut [f32]) {
        self.assert_trainable("execute_backward_data_into");
        let ctx = self.ctx();
        if self.p.stride == 1 {
            self.kernel.backward_data(
                &self.kp,
                &self.weights,
                &mut self.ws,
                gpre,
                gin,
                ctx,
            );
        } else {
            let mut full = std::mem::take(&mut self.ws.full);
            self.scatter_to_unit_stride(gpre, &mut full);
            self.kernel.backward_data(
                &self.kp,
                &self.weights,
                &mut self.ws,
                &full,
                gin,
                ctx,
            );
            self.ws.full = full;
        }
    }

    /// Backward-weight on an already-prologued gradient.
    fn execute_backward_weight_into_raw(&mut self, gpre: &[f32], x: &[f32], gw: &mut [f32]) {
        self.assert_trainable("execute_backward_weight_into");
        let ctx = self.ctx();
        if self.p.stride == 1 {
            self.kernel.backward_weight(
                &self.kp,
                &self.weights,
                &mut self.ws,
                gpre,
                x,
                gw,
                ctx,
            );
        } else {
            let mut full = std::mem::take(&mut self.ws.full);
            self.scatter_to_unit_stride(gpre, &mut full);
            self.kernel.backward_weight(
                &self.kp,
                &self.weights,
                &mut self.ws,
                &full,
                x,
                gw,
                ctx,
            );
            self.ws.full = full;
        }
    }

    /// Weight gradient against the padded input cached by the last
    /// [`Self::execute_forward_same_into`] call. Panics if no
    /// same-padding forward has populated the cache — a silently-zero
    /// gradient would stall training undetected.
    pub fn execute_backward_weight_cached_into(&mut self, gout: &[f32], gw: &mut [f32]) {
        assert!(
            self.same_cached,
            "execute_backward_weight_cached_into without a prior execute_forward_same_into"
        );
        let xp = std::mem::take(&mut self.ws.padded_in);
        self.execute_backward_weight_into(gout, &xp, gw);
        self.ws.padded_in = xp;
    }
}

/// Refresh every derived layout from `weights.kcs` (in place where the
/// buffers already exist).
fn derive_layouts(p: &ConvParams, weights: &mut PlanWeights, precision: Precision) {
    kcs_to_skc_into(&weights.kcs, p.k, p.c, p.s, &mut weights.skc);
    kcs_to_sck_flipped_into(&weights.kcs, p.k, p.c, p.s, &mut weights.sck_flip);
    if precision == Precision::Bf16 {
        if weights.skc_bf16.len() == weights.skc.len() {
            to_bf16_into(&weights.skc, &mut weights.skc_bf16);
        } else {
            weights.skc_bf16 = to_bf16(&weights.skc);
        }
    }
    if precision == Precision::I8 {
        // Per-output-channel symmetric quantization: channel k's K-row is
        // the contiguous `[k·C·S, (k+1)·C·S)` block of the framework
        // layout; quantize straight into the `(S, K, C)` forward layout
        // so steady-state `set_weights` stays allocation-free.
        if weights.w_scales.len() != p.k {
            weights.w_scales = vec![0.0; p.k];
            weights.deq = vec![0.0; p.k];
        }
        if weights.skc_i8.len() != weights.kcs.len() {
            weights.skc_i8 = vec![0; weights.kcs.len()];
        }
        for ik in 0..p.k {
            let row = &weights.kcs[ik * p.c * p.s..(ik + 1) * p.c * p.s];
            weights.w_scales[ik] = quant::scale_from_absmax(quant::absmax(row));
        }
        for ik in 0..p.k {
            let sc = weights.w_scales[ik];
            for ic in 0..p.c {
                for is in 0..p.s {
                    weights.skc_i8[(is * p.k + ik) * p.c + ic] =
                        quant::quantize(weights.kcs[(ik * p.c + ic) * p.s + is], sc);
                }
            }
        }
        for (d, &ws) in weights.deq.iter_mut().zip(&weights.w_scales) {
            *d = weights.input_scale * ws;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::test_util::rnd;
    use crate::conv1d::Conv1dLayer;

    fn problem() -> (ConvParams, Vec<f32>, Vec<f32>) {
        let p = ConvParams::new(2, 5, 7, 300, 9, 4).unwrap();
        let wt = rnd(p.k * p.c * p.s, 3);
        let x = rnd(p.n * p.c * p.w, 4);
        (p, wt, x)
    }

    #[test]
    fn registry_has_all_kernels() {
        let names: Vec<&str> = kernels().iter().map(|k| k.name()).collect();
        assert_eq!(names, vec!["brgemm", "im2col", "direct", "bf16", "i8"]);
        for alias in ["libxsmm", "onednn", "naive", "bfloat16", "OURS", "int8"] {
            assert!(lookup_kernel(alias).is_some(), "{alias}");
        }
        assert!(lookup_kernel("cuda").is_none());
    }

    #[test]
    fn kernel_names_round_trip_with_lookup() {
        for k in kernels() {
            let found = lookup_kernel(k.name()).expect("canonical name resolves");
            assert_eq!(found.name(), k.name());
        }
    }

    #[test]
    fn plan_forward_matches_layer_bit_exact() {
        let (p, wt, x) = problem();
        let layer = Conv1dLayer::new(p.c, p.k, p.s, p.d, wt.clone());
        let want = layer.forward(&x, p.n, p.w);
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt).unwrap();
        let mut got = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut got);
        assert_eq!(got, want);
        // And the owned-output convenience API agrees.
        assert_eq!(plan.execute_forward(&x), &want[..]);
    }

    #[test]
    fn all_kernels_agree_on_forward() {
        let (p, wt, x) = problem();
        let mut reference = vec![0.0; p.n * p.k * p.q()];
        ConvPlan::by_name(p, "direct", 1, wt.clone())
            .unwrap()
            .execute_forward_into(&x, &mut reference);
        for name in ["brgemm", "im2col", "bf16", "i8"] {
            let mut plan = ConvPlan::by_name(p, name, 1, wt.clone()).unwrap();
            plan.set_input_scale(quant::scale_from_absmax(quant::absmax(&x)));
            let mut got = vec![0.0; p.n * p.k * p.q()];
            plan.execute_forward_into(&x, &mut got);
            // i8's bound is the additive quantization error:
            // C·S·(Ax·sw/2 + Aw·sx/2) ≈ 45·2·0.5·(0.5/254) ≈ 0.09.
            let tol = match name {
                "bf16" => 4e-2,
                "i8" => 1.5e-1,
                _ => 1e-3,
            };
            for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
                assert!(
                    (g - r).abs() < tol * (1.0 + r.abs()),
                    "{name} idx {i}: {g} vs {r}"
                );
            }
        }
    }

    #[test]
    fn backward_passes_match_layer() {
        let (p, wt, x) = problem();
        let gout = rnd(p.n * p.k * p.q(), 9);
        let layer = Conv1dLayer::new(p.c, p.k, p.s, p.d, wt.clone());
        let gd_want = layer.backward_data(&gout, p.n, p.w);
        let gw_want = layer.backward_weight(&gout, &x, p.n, p.w);
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt).unwrap();
        let mut gd = vec![0.0; p.n * p.c * p.w];
        plan.execute_backward_data_into(&gout, &mut gd);
        let mut gw = vec![0.0; p.k * p.c * p.s];
        plan.execute_backward_weight_into(&gout, &x, &mut gw);
        assert_eq!(gd, gd_want);
        assert_eq!(gw, gw_want);
    }

    #[test]
    fn plan_reuse_is_stateless_across_inputs() {
        let (p, wt, x1) = problem();
        let x2 = rnd(p.n * p.c * p.w, 77);
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt.clone()).unwrap();
        let mut a1 = vec![0.0; p.n * p.k * p.q()];
        let mut a2 = vec![0.0; p.n * p.k * p.q()];
        let mut a1_again = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x1, &mut a1);
        plan.execute_forward_into(&x2, &mut a2);
        plan.execute_forward_into(&x1, &mut a1_again);
        assert_eq!(a1, a1_again, "plan reuse must not leak state");
        assert_ne!(a1, a2);
    }

    #[test]
    fn set_weights_refreshes_all_layouts_in_place() {
        let (p, wt, x) = problem();
        let wt2 = rnd(p.k * p.c * p.s, 55);
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt).unwrap();
        let bytes_before = plan.workspace_bytes();
        let mut before = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut before);
        plan.set_weights(&wt2);
        let mut after = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut after);
        assert_ne!(before, after);
        let mut fresh = vec![0.0; p.n * p.k * p.q()];
        ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt2)
            .unwrap()
            .execute_forward_into(&x, &mut fresh);
        assert_eq!(after, fresh);
        assert_eq!(plan.workspace_bytes(), bytes_before);
    }

    #[test]
    fn same_padding_roundtrip_with_bias() {
        let (n, c, k, s, d, wu) = (2, 3, 4, 5, 2, 97);
        let p = ConvParams::with_same_padding(n, c, k, wu, s, d).unwrap();
        let wt = rnd(k * c * s, 8);
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt.clone()).unwrap();
        plan.set_bias(&[1.0, 2.0, 3.0, 4.0]);
        let x = rnd(n * c * wu, 9);
        let mut out = vec![0.0; n * k * wu];
        plan.execute_forward_same_into(&x, &mut out);
        // Oracle: the layer's forward_same.
        let mut layer = Conv1dLayer::new(c, k, s, d, wt);
        layer.bias = vec![1.0, 2.0, 3.0, 4.0];
        let want = layer.forward_same(&x, n, wu);
        assert_eq!(out, want);
        // Cached-input backward-weight matches the explicit-input call.
        let gout = rnd(n * k * wu, 10);
        let mut gw1 = vec![0.0; k * c * s];
        plan.execute_backward_weight_cached_into(&gout, &mut gw1);
        let xp = crate::conv1d::layout::pad_width(&x, n, c, wu, plan.same_pad().0, plan.same_pad().1);
        let mut gw2 = vec![0.0; k * c * s];
        plan.execute_backward_weight_into(&gout, &xp, &mut gw2);
        assert_eq!(gw1, gw2);
        // Same-padded data gradient strips back to the unpadded width.
        let mut gx = vec![0.0; n * c * wu];
        plan.execute_backward_data_same_into(&gout, &mut gx);
        let gd_full = {
            let layer = Conv1dLayer::new(c, k, s, d, plan.weights().to_vec());
            layer.backward_data(&gout, n, p.w)
        };
        let want_gx =
            crate::conv1d::layout::unpad_width(&gd_full, n, c, p.w, plan.same_pad().0, plan.same_pad().1);
        assert_eq!(gx, want_gx);
    }

    #[test]
    fn multithreaded_plan_is_bit_exact() {
        let (p, wt, x) = problem();
        let mut p1 = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt.clone()).unwrap();
        let mut p4 = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 4, wt).unwrap();
        let mut o1 = vec![0.0; p.n * p.k * p.q()];
        let mut o4 = vec![0.0; p.n * p.k * p.q()];
        p1.execute_forward_into(&x, &mut o1);
        p4.execute_forward_into(&x, &mut o4);
        assert_eq!(o1, o4);
    }

    #[test]
    fn grid_partitioned_plan_is_bit_exact() {
        // Forward + backward-data are bit-identical across partitionings
        // (same per-block computation, different owners) — including the
        // N=1 case where only the grid actually fans out. Mirrors
        // `multithreaded_plan_is_bit_exact`.
        for name in ["brgemm", "bf16", "i8"] {
            let p = ConvParams::new(1, 5, 7, 300, 9, 4).unwrap();
            let wt = rnd(p.k * p.c * p.s, 3);
            let x = rnd(p.n * p.c * p.w, 4);
            let gout = rnd(p.n * p.k * p.q(), 5);
            let sx = quant::scale_from_absmax(quant::absmax(&x));
            let mut batch = ConvPlan::by_name(p, name, 8, wt.clone()).unwrap();
            let mut grid = ConvPlan::by_name(p, name, 8, wt.clone())
                .unwrap()
                .with_partition(Partition::Grid);
            batch.set_input_scale(sx);
            grid.set_input_scale(sx);
            assert_eq!(batch.partition(), Partition::Batch);
            assert_eq!(grid.partition(), Partition::Grid);
            assert_eq!(batch.isa(), grid.isa());
            let (mut ob, mut og) = (
                vec![0.0; p.n * p.k * p.q()],
                vec![0.0; p.n * p.k * p.q()],
            );
            batch.execute_forward_into(&x, &mut ob);
            grid.execute_forward_into(&x, &mut og);
            assert_eq!(ob, og, "{name}: forward grid vs batch");
            let (mut gb, mut gg) = (
                vec![0.0; p.n * p.c * p.w],
                vec![0.0; p.n * p.c * p.w],
            );
            batch.execute_backward_data_into(&gout, &mut gb);
            grid.execute_backward_data_into(&gout, &mut gg);
            assert_eq!(gb, gg, "{name}: backward-data grid vs batch");
            // Backward-weight shards accumulators differently; agree to
            // fp-reassociation tolerance.
            let (mut wb, mut wg) = (
                vec![0.0; p.k * p.c * p.s],
                vec![0.0; p.k * p.c * p.s],
            );
            batch.execute_backward_weight_into(&gout, &x, &mut wb);
            grid.execute_backward_weight_into(&gout, &x, &mut wg);
            for (a, b) in wb.iter().zip(&wg) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn workspace_bytes_reflects_kernel_needs() {
        let (p, wt, _x) = problem();
        let direct = ConvPlan::by_name(p, "direct", 1, wt.clone()).unwrap();
        let im2col = ConvPlan::by_name(p, "im2col", 1, wt.clone()).unwrap();
        let brgemm = ConvPlan::by_name(p, "brgemm", 1, wt).unwrap();
        // im2col's patch matrix dominates everything else.
        assert!(im2col.workspace_bytes() > brgemm.workspace_bytes());
        assert!(brgemm.workspace_bytes() > direct.workspace_bytes());
        // The registry's size query agrees with the plan's allocation
        // modulo the always-present tap-offset tables (the lazy
        // padded_in/gx_padded/out buffers are empty at build time).
        let kernel = lookup_kernel("im2col").unwrap();
        let fixed = (forward_a_offs(&p).len() + backward_data_a_offs(&p).len())
            * std::mem::size_of::<usize>();
        assert_eq!(kernel.workspace_bytes(&p, 1) + fixed, im2col.workspace_bytes());
    }

    #[test]
    fn fused_post_ops_match_reference_sweep_bit_exact() {
        let (p, wt, x) = problem();
        let bias = rnd(p.k, 77);
        let res = rnd(p.n * p.k * p.q(), 78);
        let combos = [
            PostOps::none(),
            PostOps::bias(),
            PostOps::bias_relu(),
            PostOps::parse("bias_sigmoid").unwrap(),
            PostOps::bias_relu_residual().with_scale(0.5),
        ];
        for name in ["brgemm", "im2col", "direct", "bf16", "i8"] {
            for &ops in combos.iter() {
                let mut plan = ConvPlan::by_name(p, name, 1, wt.clone())
                    .unwrap()
                    .with_post_ops(ops);
                plan.set_bias(&bias);
                plan.set_input_scale(quant::scale_from_absmax(quant::absmax(&x)));
                let residual = if ops.residual { Some(&res[..]) } else { None };
                let mut fused = vec![0.0; p.n * p.k * p.q()];
                plan.execute_forward_post_into(&x, residual, &mut fused);
                // Oracle: the same plan's raw forward + the unfused
                // reference sweep. The fused path reorders nothing, so
                // the comparison is bit-exact per kernel.
                let mut want = vec![0.0; p.n * p.k * p.q()];
                plan.execute_forward_into(&x, &mut want);
                post::apply_reference(&ops, &bias, residual, &mut want, p.n, p.k, p.q());
                assert_eq!(fused, want, "{name} / {ops}");
            }
        }
    }

    #[test]
    fn strided_plans_subsample_the_unit_stride_output() {
        let p1 = ConvParams::new(2, 3, 4, 50, 5, 2).unwrap(); // Q = 42
        let p2 = p1.with_stride(2).unwrap(); // Q = 21
        let wt = rnd(4 * 3 * 5, 5);
        let x = rnd(2 * 3 * 50, 6);
        let mut full = vec![0.0; 2 * 4 * p1.q()];
        ConvPlan::by_name(p1, "brgemm", 1, wt.clone())
            .unwrap()
            .execute_forward_into(&x, &mut full);
        for name in ["brgemm", "im2col", "direct", "bf16", "i8"] {
            let mut plan = ConvPlan::by_name(p2, name, 1, wt.clone()).unwrap();
            plan.set_input_scale(quant::scale_from_absmax(quant::absmax(&x)));
            assert_eq!(plan.params().q(), 21);
            let mut out = vec![0.0; 2 * 4 * p2.q()];
            plan.execute_forward_into(&x, &mut out);
            let tol = match name {
                "bf16" => 4e-2,
                "i8" => 1e-1,
                _ => 1e-4,
            };
            for row in 0..2 * 4 {
                for j in 0..p2.q() {
                    let want = full[row * p1.q() + j * 2];
                    let got = out[row * p2.q() + j];
                    assert!(
                        (got - want).abs() < tol * (1.0 + want.abs()),
                        "{name} row {row} col {j}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn inference_plan_trims_backward_scratch_and_keeps_forward_bits() {
        let (p, wt, x) = problem();
        for name in ["brgemm", "im2col", "bf16", "i8"] {
            let sx = quant::scale_from_absmax(quant::absmax(&x));
            let mut full = ConvPlan::by_name(p, name, 4, wt.clone()).unwrap();
            let mut inf = ConvPlan::by_name(p, name, 4, wt.clone())
                .unwrap()
                .with_inference();
            full.set_input_scale(sx);
            inf.set_input_scale(sx);
            assert!(inf.is_inference() && !full.is_inference());
            assert!(
                inf.workspace_bytes() < full.workspace_bytes(),
                "{name}: inference workspace {} !< training {}",
                inf.workspace_bytes(),
                full.workspace_bytes()
            );
            let (mut a, mut b) = (
                vec![0.0; p.n * p.k * p.q()],
                vec![0.0; p.n * p.k * p.q()],
            );
            full.execute_forward_into(&x, &mut a);
            inf.execute_forward_into(&x, &mut b);
            assert_eq!(a, b, "{name}: inference forward must be bit-identical");
        }
    }

    #[test]
    #[should_panic(expected = "inference-only plan")]
    fn inference_plan_refuses_backward_data() {
        let (p, wt, _x) = problem();
        let mut plan = ConvPlan::by_name(p, "brgemm", 1, wt).unwrap().with_inference();
        let gout = vec![0.0; p.n * p.k * p.q()];
        let mut gin = vec![0.0; p.n * p.c * p.w];
        plan.execute_backward_data_into(&gout, &mut gin);
    }

    #[test]
    #[should_panic(expected = "inference-only plan")]
    fn inference_plan_refuses_backward_weight() {
        let (p, wt, x) = problem();
        let mut plan = ConvPlan::by_name(p, "brgemm", 1, wt).unwrap().with_inference();
        let gout = vec![0.0; p.n * p.k * p.q()];
        let mut gw = vec![0.0; p.k * p.c * p.s];
        plan.execute_backward_weight_into(&gout, &x, &mut gw);
    }

    #[test]
    fn rejects_bad_configurations() {
        let p = ConvParams::new(1, 2, 3, 50, 5, 2).unwrap();
        let wt = rnd(3 * 2 * 5, 1);
        assert!(ConvPlan::by_name(p, "no-such-kernel", 1, wt.clone()).is_err());
        assert!(ConvPlan::new(p, Backend::Im2col, Precision::Bf16, 1, wt.clone()).is_err());
        assert!(ConvPlan::new(p, Backend::Im2col, Precision::I8, 1, wt.clone()).is_err());
        assert!(ConvPlan::new(p, Backend::Direct, Precision::I8, 1, wt.clone()).is_err());
        assert!(ConvPlan::by_name(p, "brgemm", 1, wt[1..].to_vec()).is_err());
    }

    #[test]
    fn i8_plan_set_input_scale_refreshes_deq_and_changes_output() {
        let (p, wt, x) = problem();
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::I8, 1, wt).unwrap();
        assert_eq!(plan.precision(), Precision::I8);
        assert_eq!(plan.input_scale(), 1.0);
        let mut coarse = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut coarse);
        // Calibrate to the actual input range: with scale 1.0, rnd inputs
        // in [-0.5, 0.5) all quantize to 0 — calibration is load-bearing.
        assert!(coarse.iter().all(|&v| v == 0.0));
        let sx = quant::scale_from_absmax(quant::absmax(&x));
        plan.set_input_scale(sx);
        assert_eq!(plan.input_scale(), sx);
        let mut calibrated = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut calibrated);
        assert!(calibrated.iter().any(|&v| v != 0.0));
        // Oracle: direct conv over the dequantized operands.
        let mut want = vec![0.0; p.n * p.k * p.q()];
        let xdq: Vec<f32> = x
            .iter()
            .map(|&v| quant::quantize(v, sx) as f32 * sx)
            .collect();
        let wdq: Vec<f32> = plan
            .weights()
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let sc = plan.weights.w_scales[i / (p.c * p.s)];
                quant::quantize(v, sc) as f32 * sc
            })
            .collect();
        crate::conv1d::direct::forward_direct(&p, &xdq, &wdq, &mut want);
        for (g, w_) in calibrated.iter().zip(&want) {
            assert!((g - w_).abs() < 1e-3 * (1.0 + w_.abs()), "{g} vs {w_}");
        }
    }
}
