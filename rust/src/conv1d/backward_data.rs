//! Backward-data pass — paper Algorithm 3 (width-blocked BRGEMM).
//!
//! The paper relays the weight out to `(S, C, K)` and walks the output
//! gradient with reversed tap pointers (`B_ptrs[s] = &Grad_out[0,
//! pos − (S−1−s)·d]`), zero-padding `Grad_out` "wherever needed".
//! Equivalently (substitute `s' = S−1−s`): pad `Grad_out` by `(S−1)·d`
//! zeros on both sides and run the *forward* block loop over the
//! tap-reversed `(S, C, K)` weight. That is exactly what this module does,
//! so the backward-data pass shares the forward BRGEMM machinery — the
//! same property the paper exploits ("very similar to the forward pass").
//!
//! The batched entry point takes an [`ExecCtx`]; under
//! [`Partition::Grid`] the `N × ceil(W/64)` grid of *data-gradient*
//! width blocks is split across workers, so a single long image
//! parallelises its backward too.

use super::brgemm::brgemm_f32_with;
use super::params::{ConvParams, WIDTH_BLOCK};
use super::simd::{self, MicroKernelSet};
use super::threading::{
    par_batch_chunks_scratch, par_grid_chunks_scratch, ExecCtx, GridStripe, Partition,
};

/// Tap offsets of the `(S, C, K)` backward-data weight: `a_offs[s] = s·C·K`.
pub fn backward_data_a_offs(p: &ConvParams) -> Vec<usize> {
    (0..p.s).map(|is| is * p.c * p.k).collect()
}

/// One `(C, nb)` data-gradient block at column `pos` of one image — the
/// unit of work of both partitionings.
#[allow(clippy::too_many_arguments)]
#[inline]
fn backward_data_block(
    uks: &MicroKernelSet,
    p: &ConvParams,
    gout_padded: &[f32],
    w_sck: &[f32],
    gin_row: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
    pos: usize,
    nb: usize,
) {
    let (c, k, d, w, q) = (p.c, p.k, p.d, p.w, p.q());
    let qp = q + 2 * (p.s - 1) * d;
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d; // into the padded gradient
    }
    brgemm_f32_with(
        uks,
        w_sck,
        a_offs,
        k,
        gout_padded,
        b_offs,
        qp,
        &mut gin_row[pos..],
        w,
        c,
        nb,
        k,
        true,
    );
}

/// [`backward_data_block`] for a grid worker: BRGEMM into the worker's
/// private contiguous `(C, nb)` staging block (`ldc = nb` — identical
/// math, `ldc` only moves stores), then store only the worker's own
/// column stripe of the shared data-gradient row through the
/// [`GridStripe`] handle — no aliasing `&mut` over the output, ever.
#[allow(clippy::too_many_arguments)]
#[inline]
fn backward_data_block_grid(
    uks: &MicroKernelSet,
    p: &ConvParams,
    gout_padded: &[f32],
    w_sck: &[f32],
    stripe: &mut GridStripe<'_, f32>,
    a_offs: &[usize],
    b_offs: &mut [usize],
    stage: &mut [f32],
    pos: usize,
    nb: usize,
) {
    let (c, k, d, q) = (p.c, p.k, p.d, p.q());
    let qp = q + 2 * (p.s - 1) * d;
    for (is, bo) in b_offs.iter_mut().enumerate() {
        *bo = pos + is * d; // into the padded gradient
    }
    let stage = &mut stage[..c * nb];
    brgemm_f32_with(uks, w_sck, a_offs, k, gout_padded, b_offs, qp, stage, nb, c, nb, k, true);
    stripe.store_block(stage);
}

/// Zero-allocation backward-data for one batch element; offset tables are
/// caller-owned scratch.
///
/// * `gout_padded`: `(K, Q + 2·(S−1)·d)` — output gradient padded with
///   `(S−1)·d` zeros on each side (see [`pad_gout_into`]).
/// * `w_sck`: weight relaid out to `(S, C, K)` with taps reversed
///   ([`super::layout::kcs_to_sck_flipped`]).
/// * `gin`: `(C, W)` data gradient, overwritten.
pub fn backward_data_single_into(
    p: &ConvParams,
    gout_padded: &[f32],
    w_sck: &[f32],
    gin: &mut [f32],
    a_offs: &[usize],
    b_offs: &mut [usize],
) {
    let (c, k, s, d, w, q) = (p.c, p.k, p.s, p.d, p.w, p.q());
    let pad = (s - 1) * d;
    let qp = q + 2 * pad;
    debug_assert_eq!(gout_padded.len(), k * qp);
    debug_assert_eq!(w_sck.len(), s * c * k);
    debug_assert_eq!(gin.len(), c * w);
    debug_assert_eq!(a_offs.len(), s);
    debug_assert_eq!(b_offs.len(), s);
    let uks = simd::active();
    // The "output" of this pass is the data gradient of width W = Q + pad.
    let mut pos = 0;
    while pos < w {
        let nb = WIDTH_BLOCK.min(w - pos);
        backward_data_block(uks, p, gout_padded, w_sck, gin, a_offs, b_offs, pos, nb);
        pos += nb;
    }
}

/// Backward-data for one batch element (allocating wrapper).
pub fn backward_data_single(p: &ConvParams, gout_padded: &[f32], w_sck: &[f32], gin: &mut [f32]) {
    let a_offs = backward_data_a_offs(p);
    let mut b_offs = vec![0usize; p.s];
    backward_data_single_into(p, gout_padded, w_sck, gin, &a_offs, &mut b_offs);
}

/// Zero-pad the `(N, K, Q)` output gradient by `(S−1)·d` on both width
/// edges into a caller-owned `(N, K, Q + 2·(S−1)·d)` buffer.
pub fn pad_gout_into(p: &ConvParams, gout: &[f32], gp: &mut [f32]) {
    let (n, k, q) = (p.n, p.k, p.q());
    let pad = (p.s - 1) * p.d;
    super::layout::pad_width_into(gout, n, k, q, pad, pad, gp);
}

/// Zero-pad `(N, K, Q)` output gradient by `(S−1)·d` on both width edges.
pub fn pad_gout(p: &ConvParams, gout: &[f32]) -> Vec<f32> {
    let (n, k, q) = (p.n, p.k, p.q());
    let pad = (p.s - 1) * p.d;
    let mut gp = vec![0.0; n * k * (q + 2 * pad)];
    pad_gout_into(p, gout, &mut gp);
    gp
}

/// Batched backward-data with caller-owned scratch — the plan executor's
/// entry point. `b_offs` needs one `S`-window per effective worker, `gp`
/// the padded-gradient size `N·K·(Q + 2·(S−1)·d)`; under
/// [`Partition::Grid`] `stage` must additionally hold one
/// `C·WIDTH_BLOCK` f32 staging window per effective worker (unused — may
/// be empty — under [`Partition::Batch`]). With `ctx.threads <= 1` the
/// call performs zero heap allocations.
#[allow(clippy::too_many_arguments)]
pub fn backward_data_with_scratch(
    p: &ConvParams,
    gout: &[f32],
    w_sck: &[f32],
    gin: &mut [f32],
    ctx: ExecCtx,
    a_offs: &[usize],
    b_offs: &mut [usize],
    gp: &mut [f32],
    stage: &mut [f32],
) {
    let (n, c, k, w, q) = (p.n, p.c, p.k, p.w, p.q());
    assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch for {p}");
    assert_eq!(w_sck.len(), p.s * c * k, "weight shape mismatch for {p}");
    assert_eq!(gin.len(), n * c * w, "grad-in shape mismatch for {p}");
    pad_gout_into(p, gout, gp);
    let qp = q + 2 * (p.s - 1) * p.d;
    let gp = &*gp;
    let uks = ctx.uks;
    let mut no_scratch: [f32; 0] = [];
    match ctx.partition {
        Partition::Batch => par_batch_chunks_scratch(
            gin,
            c * w,
            b_offs,
            p.s,
            &mut no_scratch[..],
            0,
            ctx.threads,
            |i, gin_row, bo, _| {
                let gp_row = &gp[i * k * qp..(i + 1) * k * qp];
                let mut pos = 0;
                while pos < w {
                    let nb = WIDTH_BLOCK.min(w - pos);
                    backward_data_block(uks, p, gp_row, w_sck, gin_row, a_offs, bo, pos, nb);
                    pos += nb;
                }
            },
        ),
        Partition::Grid => par_grid_chunks_scratch(
            gin,
            c * w,
            w,
            WIDTH_BLOCK,
            b_offs,
            p.s,
            stage,
            c * WIDTH_BLOCK,
            ctx.threads,
            |i, pos, nb, stripe, bo, stg| {
                let gp_row = &gp[i * k * qp..(i + 1) * k * qp];
                backward_data_block_grid(uks, p, gp_row, w_sck, stripe, a_offs, bo, stg, pos, nb);
            },
        ),
    }
}

/// Batched backward-data pass, threaded over the batch dimension. The pad
/// buffer and offset tables are hoisted to one allocation per call.
///
/// * `gout`: `(N, K, Q)` (unpadded); `w_sck` as above; `gin`: `(N, C, W)`.
pub fn backward_data(p: &ConvParams, gout: &[f32], w_sck: &[f32], gin: &mut [f32], threads: usize) {
    let a_offs = backward_data_a_offs(p);
    let workers = threads.max(1).min(p.n.max(1));
    let mut b_offs = vec![0usize; workers * p.s];
    let qp = p.q() + 2 * (p.s - 1) * p.d;
    let mut gp = vec![0.0; p.n * p.k * qp];
    let mut stage: [f32; 0] = []; // batch partitioning needs no staging
    backward_data_with_scratch(
        p,
        gout,
        w_sck,
        gin,
        ExecCtx::with_threads(threads),
        &a_offs,
        &mut b_offs,
        &mut gp,
        &mut stage,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::direct::backward_data_direct;
    use crate::conv1d::layout::kcs_to_sck_flipped;
    use crate::conv1d::test_util::rnd;

    fn check(p: ConvParams) {
        let gout = rnd(p.n * p.k * p.q(), 10);
        let wt = rnd(p.k * p.c * p.s, 20);
        let sck = kcs_to_sck_flipped(&wt, p.k, p.c, p.s);
        let mut got = vec![0.0; p.n * p.c * p.w];
        backward_data(&p, &gout, &sck, &mut got, 1);
        let mut want = vec![0.0; p.n * p.c * p.w];
        backward_data_direct(&p, &gout, &wt, &mut want);
        for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w_).abs() < 1e-4 * (1.0 + w_.abs()),
                "{p} idx {i}: {g} vs {w_}"
            );
        }
    }

    #[test]
    fn matches_direct_paper_shapes() {
        for &(n, c, k, q, s, d) in &[
            (2, 15, 15, 128, 51, 8),
            (1, 64, 64, 200, 5, 1),
            (2, 32, 32, 130, 9, 4),
            (1, 1, 1, 64, 1, 1),
            (1, 4, 8, 100, 15, 2),
            (3, 10, 16, 77, 21, 1),
            (1, 8, 4, 640, 25, 16),
        ] {
            check(ConvParams::new(n, c, k, q + (s - 1) * d, s, d).unwrap());
        }
    }

    #[test]
    fn threaded_equals_single() {
        let p = ConvParams::new(5, 6, 7, 300, 9, 3).unwrap();
        let gout = rnd(p.n * p.k * p.q(), 30);
        let wt = rnd(p.k * p.c * p.s, 40);
        let sck = kcs_to_sck_flipped(&wt, p.k, p.c, p.s);
        let mut g1 = vec![0.0; p.n * p.c * p.w];
        let mut g3 = vec![0.0; p.n * p.c * p.w];
        backward_data(&p, &gout, &sck, &mut g1, 1);
        backward_data(&p, &gout, &sck, &mut g3, 3);
        assert_eq!(g1, g3);
    }

    #[test]
    fn grid_partition_equals_batch_bit_exact() {
        // Grid split over the data-gradient width blocks — bit-exact vs
        // batch, including the N=1 single-image fan-out.
        for &(n, threads) in &[(1usize, 8usize), (3, 4)] {
            let p = ConvParams::new(n, 5, 6, 333, 7, 3).unwrap();
            let gout = rnd(p.n * p.k * p.q(), 60);
            let wt = rnd(p.k * p.c * p.s, 61);
            let sck = kcs_to_sck_flipped(&wt, p.k, p.c, p.s);
            let a_offs = backward_data_a_offs(&p);
            let qp = p.q() + 2 * (p.s - 1) * p.d;
            let run = |partition| {
                let ctx = ExecCtx::new(threads, partition);
                let mut b_offs = vec![0usize; threads.max(1) * p.s];
                let mut gp = vec![0.0; p.n * p.k * qp];
                let mut stage = vec![0.0f32; threads.max(1) * p.c * WIDTH_BLOCK];
                let mut gin = vec![0.0; p.n * p.c * p.w];
                backward_data_with_scratch(
                    &p, &gout, &sck, &mut gin, ctx, &a_offs, &mut b_offs, &mut gp, &mut stage,
                );
                gin
            };
            assert_eq!(
                run(Partition::Batch),
                run(Partition::Grid),
                "N={n} threads={threads}"
            );
        }
    }

    #[test]
    fn s1_is_transpose_matmul() {
        // With S=1 the data gradient is Wᵀ·gout, width-preserving.
        let p = ConvParams::new(1, 2, 3, 50, 1, 4).unwrap();
        let gout = rnd(p.k * p.q(), 50);
        let wt = rnd(p.k * p.c, 60); // (K, C, 1)
        let sck = kcs_to_sck_flipped(&wt, p.k, p.c, 1);
        let mut gin = vec![0.0; p.c * p.w];
        backward_data(&p, &gout, &sck, &mut gin, 1);
        for ic in 0..p.c {
            for iq in 0..p.q() {
                let mut want = 0.0;
                for ik in 0..p.k {
                    want += wt[ik * p.c + ic] * gout[ik * p.q() + iq];
                }
                assert!((gin[ic * p.w + iq] - want).abs() < 1e-5);
            }
        }
    }
}
