//! Weight-tensor relayouts (paper Sec. 3.1 / 3.2).
//!
//! The framework-native weight layout is `(K, C, S)`. The forward pass
//! consumes `(S, K, C)` — each tap `s` is then a contiguous `(K, C)` GEMM
//! operand — and the backward-data pass consumes `(S, C, K)` with the tap
//! axis *reversed*, which realises Algorithm 3's pointer walk
//! `B_ptrs[s] = &Grad_out[0, pos − (S−1−s)·d]` as a plain forward-style
//! BRGEMM over a zero-padded gradient.

/// `(K, C, S) → (S, K, C)` into a caller-owned buffer (plan steady state:
/// `set_weights` re-derives layouts with zero allocations).
pub fn kcs_to_skc_into(w: &[f32], k: usize, c: usize, s: usize, out: &mut [f32]) {
    assert_eq!(w.len(), k * c * s, "weight length mismatch");
    assert_eq!(out.len(), k * c * s, "layout buffer length mismatch");
    for ik in 0..k {
        for ic in 0..c {
            for is in 0..s {
                out[(is * k + ik) * c + ic] = w[(ik * c + ic) * s + is];
            }
        }
    }
}

/// `(K, C, S) → (S, K, C)`. Forward-pass layout (paper Sec. 3.1).
pub fn kcs_to_skc(w: &[f32], k: usize, c: usize, s: usize) -> Vec<f32> {
    let mut out = vec![0.0; k * c * s];
    kcs_to_skc_into(w, k, c, s, &mut out);
    out
}

/// `(K, C, S) → (S, K, C)` for i8 weights, into a caller-owned buffer —
/// the quantized forward layout (i8 tier quantizes in the framework-native
/// `(K, C, S)` layout, where per-output-channel rows are contiguous, then
/// relays out like f32).
pub fn kcs_to_skc_i8_into(w: &[i8], k: usize, c: usize, s: usize, out: &mut [i8]) {
    assert_eq!(w.len(), k * c * s, "weight length mismatch");
    assert_eq!(out.len(), k * c * s, "layout buffer length mismatch");
    for ik in 0..k {
        for ic in 0..c {
            for is in 0..s {
                out[(is * k + ik) * c + ic] = w[(ik * c + ic) * s + is];
            }
        }
    }
}

/// `(K, C, S) → (S, K, C)` for i8 weights.
pub fn kcs_to_skc_i8(w: &[i8], k: usize, c: usize, s: usize) -> Vec<i8> {
    let mut out = vec![0i8; k * c * s];
    kcs_to_skc_i8_into(w, k, c, s, &mut out);
    out
}

/// `(K, C, S) → (S, C, K)` with the tap axis reversed, into a caller-owned
/// buffer.
pub fn kcs_to_sck_flipped_into(w: &[f32], k: usize, c: usize, s: usize, out: &mut [f32]) {
    assert_eq!(w.len(), k * c * s, "weight length mismatch");
    assert_eq!(out.len(), k * c * s, "layout buffer length mismatch");
    for ik in 0..k {
        for ic in 0..c {
            for is in 0..s {
                out[((s - 1 - is) * c + ic) * k + ik] = w[(ik * c + ic) * s + is];
            }
        }
    }
}

/// `(K, C, S) → (S, C, K)` with the tap axis reversed.
/// Backward-data layout (paper Sec. 3.2); the flip encodes `s → S−1−s`.
pub fn kcs_to_sck_flipped(w: &[f32], k: usize, c: usize, s: usize) -> Vec<f32> {
    let mut out = vec![0.0; k * c * s];
    kcs_to_sck_flipped_into(w, k, c, s, &mut out);
    out
}

/// `(S, C, K) → (K, C, S)` into a caller-owned buffer (the zero-allocation
/// tail of the backward-weight pass).
pub fn sck_to_kcs_into(w: &[f32], s: usize, c: usize, k: usize, out: &mut [f32]) {
    assert_eq!(w.len(), k * c * s, "weight length mismatch");
    assert_eq!(out.len(), k * c * s, "layout buffer length mismatch");
    for is in 0..s {
        for ic in 0..c {
            for ik in 0..k {
                out[(ik * c + ic) * s + is] = w[(is * c + ic) * k + ik];
            }
        }
    }
}

/// `(S, C, K) → (K, C, S)`. Inverse of the backward-weight accumulator
/// layout: Algorithm 4 accumulates `Grad_w` in `(S, C, K)` panels and the
/// framework stores gradients in `(K, C, S)`.
pub fn sck_to_kcs(w: &[f32], s: usize, c: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0; k * c * s];
    sck_to_kcs_into(w, s, c, k, &mut out);
    out
}

/// `(S, K, C) → (K, C, S)`. Inverse of [`kcs_to_skc`]; used by tests and
/// by checkpoint export.
pub fn skc_to_kcs(w: &[f32], s: usize, k: usize, c: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * c * s, "weight length mismatch");
    let mut out = vec![0.0; k * c * s];
    for is in 0..s {
        for ik in 0..k {
            for ic in 0..c {
                out[(ik * c + ic) * s + is] = w[(is * k + ik) * c + ic];
            }
        }
    }
    out
}

/// Zero-pad a `(N, C, W)` tensor along the width axis into a caller-owned
/// buffer (pad regions are rewritten, so the buffer may hold stale data).
pub fn pad_width_into(
    x: &[f32],
    n: usize,
    c: usize,
    w: usize,
    left: usize,
    right: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c * w, "input length mismatch");
    let wp = w + left + right;
    assert_eq!(out.len(), n * c * wp, "padded buffer length mismatch");
    for row in 0..n * c {
        let base = row * wp;
        out[base..base + left].fill(0.0);
        out[base + left..base + left + w].copy_from_slice(&x[row * w..(row + 1) * w]);
        out[base + left + w..base + wp].fill(0.0);
    }
}

/// Zero-pad a `(N, C, W)` tensor along the width axis.
pub fn pad_width(x: &[f32], n: usize, c: usize, w: usize, left: usize, right: usize) -> Vec<f32> {
    let mut out = vec![0.0; n * c * (w + left + right)];
    pad_width_into(x, n, c, w, left, right, &mut out);
    out
}

/// Remove `left`/`right` columns from a `(N, C, W)` tensor into a
/// caller-owned buffer.
pub fn unpad_width_into(
    x: &[f32],
    n: usize,
    c: usize,
    w: usize,
    left: usize,
    right: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c * w, "input length mismatch");
    let wu = w - left - right;
    assert_eq!(out.len(), n * c * wu, "unpadded buffer length mismatch");
    for row in 0..n * c {
        let src = &x[row * w + left..row * w + left + wu];
        out[row * wu..(row + 1) * wu].copy_from_slice(src);
    }
}

/// Remove `left`/`right` columns from a `(N, C, W)` tensor.
pub fn unpad_width(x: &[f32], n: usize, c: usize, w: usize, left: usize, right: usize) -> Vec<f32> {
    let mut out = vec![0.0; n * c * (w - left - right)];
    unpad_width_into(x, n, c, w, left, right, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iota(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32).collect()
    }

    #[test]
    fn skc_roundtrip() {
        let (k, c, s) = (4, 3, 5);
        let w = iota(k * c * s);
        assert_eq!(skc_to_kcs(&kcs_to_skc(&w, k, c, s), s, k, c), w);
    }

    #[test]
    fn sck_flip_semantics() {
        let (k, c, s) = (2, 3, 4);
        let w = iota(k * c * s);
        let sck = kcs_to_sck_flipped(&w, k, c, s);
        for is in 0..s {
            for ic in 0..c {
                for ik in 0..k {
                    assert_eq!(
                        sck[(is * c + ic) * k + ik],
                        w[(ik * c + ic) * s + (s - 1 - is)],
                    );
                }
            }
        }
    }

    #[test]
    fn sck_to_kcs_inverts_unflipped_layout() {
        // Build an (S,C,K) tensor directly and check indexing convention.
        let (s, c, k) = (3, 2, 4);
        let sck = iota(s * c * k);
        let kcs = sck_to_kcs(&sck, s, c, k);
        for is in 0..s {
            for ic in 0..c {
                for ik in 0..k {
                    assert_eq!(kcs[(ik * c + ic) * s + is], sck[(is * c + ic) * k + ik]);
                }
            }
        }
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let (n, c, w) = (2, 3, 7);
        let x = iota(n * c * w);
        let padded = pad_width(&x, n, c, w, 2, 5);
        assert_eq!(padded.len(), n * c * (w + 7));
        assert_eq!(unpad_width(&padded, n, c, w + 7, 2, 5), x);
        // Edges are zero.
        assert_eq!(padded[0], 0.0);
        assert_eq!(padded[1], 0.0);
        assert_eq!(padded[2], 0.0); // first data element is x[0] == 0 too
        assert_eq!(padded[3], 1.0);
    }
}
