//! In-tree substrates that replace external crates in the offline build:
//! a JSON reader ([`json`]), a splitmix/xoshiro PRNG with distribution
//! samplers ([`rng`]), and small shared helpers.

pub mod json;
pub mod rng;

/// Format a byte count human-readably (benchmark reports).
pub fn human_bytes(b: u64) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i < U.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", U[i])
    }
}

/// Format seconds adaptively (ns → s) for benchmark tables.
pub fn human_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(2.5), "2.500 s");
        assert_eq!(human_secs(0.0025), "2.500 ms");
        assert_eq!(human_secs(2.5e-6), "2.500 µs");
        assert_eq!(human_secs(5e-9), "5.0 ns");
    }
}
