//! Minimal JSON parser — substrate for reading `artifacts/meta.json`.
//!
//! The offline build has no serde; this is a small, strict, recursive-
//! descent parser covering the full JSON grammar (RFC 8259) minus the
//! exotic corners we never emit (`\u` surrogate pairs are decoded, numbers
//! are f64, no comments). It is only used on the control path (artifact
//! registry, config), never on the training hot path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object member access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1, 2, 3]` → `vec![1, 2, 3]` for shape lists.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, ch: u8) -> Result<(), JsonError> {
        if self.peek() == Some(ch) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", ch as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair.
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let frag = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(frag);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": null}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Null));
    }

    #[test]
    fn shape_vectors() {
        let v = Json::parse("[4, 15, 1424]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![4, 15, 1424]));
        assert_eq!(Json::parse("[1.5]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\" 1}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parses_real_meta_fragment() {
        let doc = r#"{
          "conv_fwd_atac": {
            "kind": "conv_fwd",
            "params": {"n": 4, "c": 15, "k": 15, "q": 1024, "s": 51, "d": 8, "w": 1424},
            "inputs": [{"dtype": "f32", "shape": [4, 15, 1424]}],
            "flops": 962150400
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        let e = v.get("conv_fwd_atac").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("conv_fwd"));
        assert_eq!(e.get("params").unwrap().get("s").unwrap().as_usize(), Some(51));
        assert_eq!(
            e.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap().as_usize_vec(),
            Some(vec![4, 15, 1424])
        );
    }
}
