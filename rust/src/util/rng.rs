//! Deterministic PRNG + distribution samplers — the data-generation
//! substrate (no `rand` crate in the offline build).
//!
//! `SplitMix64` seeds a `Xoshiro256++` core; on top we provide uniform,
//! normal (Box–Muller), Poisson (Knuth / PTRS for large λ) and lognormal
//! samplers — everything the synthetic ATAC-seq generator needs.

/// Xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit value (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift bounded sampler (bias ≤ 2^-64·n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Lognormal: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson sample. Knuth's product method for small λ, normal
    /// approximation (rounded, clamped at 0) for λ > 30 — plenty for
    /// coverage-track synthesis where λ is O(1..100).
    pub fn poisson(&mut self, lambda: f64) -> u32 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = self.normal(lambda, lambda.sqrt()).round();
            return v.max(0.0) as u32;
        }
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // unreachable for λ ≤ 30; guard anyway
            }
        }
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fill a slice with `N(0, std)` f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal(0.0, std as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut m = 0.0;
        let mut m2 = 0.0;
        for _ in 0..n {
            let g = r.gauss();
            m += g;
            m2 += g * g;
        }
        m /= n as f64;
        m2 /= n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut r = Rng::new(13);
        for &lam in &[0.5, 3.0, 12.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.05,
                "λ={lam}: mean {mean}"
            );
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(17);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(21);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        let a: Vec<u64> = (0..4).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
