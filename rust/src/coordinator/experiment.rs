//! Experiment descriptors: the paper's published numbers, encoded so the
//! benchmark harness can print paper-vs-reproduced tables (DESIGN.md §8).

/// One row of paper Table 1 (single-socket end-to-end training).
#[derive(Debug, Clone, Copy)]
pub struct Table1Row {
    pub device: &'static str,
    pub code: &'static str,
    pub precision: &'static str,
    /// Training time per epoch, seconds ("—" encoded as NaN for V100).
    pub time_per_epoch: f64,
    pub auroc: f64,
}

/// Paper Table 1 (Sec. 4.4).
pub const TABLE1: &[Table1Row] = &[
    Table1Row { device: "1 V100", code: "CUDA", precision: "FP32", time_per_epoch: f64::NAN, auroc: 0.9386 },
    Table1Row { device: "1s CLX", code: "oneDNN", precision: "FP32", time_per_epoch: 9690.4, auroc: 0.9388 },
    Table1Row { device: "1s CLX", code: "LIBXSMM", precision: "FP32", time_per_epoch: 1411.9, auroc: 0.9388 },
    Table1Row { device: "1s CPX", code: "LIBXSMM", precision: "FP32", time_per_epoch: 1254.8, auroc: 0.9387 },
    Table1Row { device: "1s CPX", code: "LIBXSMM", precision: "BF16", time_per_epoch: 769.6, auroc: 0.9378 },
];

/// Headline single-socket speedup of Table 1: oneDNN / LIBXSMM on CLX.
pub fn table1_clx_speedup() -> f64 {
    9690.4 / 1411.9 // = 6.86×
}

/// One row of paper Table 2 (16-socket vs DGX-1).
#[derive(Debug, Clone, Copy)]
pub struct Table2Row {
    pub device: &'static str,
    pub precision: &'static str,
    pub time_per_epoch: f64,
    pub auroc: f64,
    pub speedup_vs_v100: f64,
}

/// Paper Table 2 (Sec. 4.5.2).
pub const TABLE2: &[Table2Row] = &[
    Table2Row { device: "8 V100", precision: "FP32", time_per_epoch: 162.0, auroc: f64::NAN, speedup_vs_v100: 1.00 },
    Table2Row { device: "16s CLX", precision: "FP32", time_per_epoch: 115.0, auroc: 0.9345, speedup_vs_v100: 1.41 },
    Table2Row { device: "16s CPX", precision: "FP32", time_per_epoch: 103.1, auroc: 0.9341, speedup_vs_v100: 1.57 },
    Table2Row { device: "8s CPX", precision: "BF16", time_per_epoch: 122.8, auroc: 0.9346, speedup_vs_v100: 1.32 },
    Table2Row { device: "16s CPX", precision: "BF16", time_per_epoch: 71.3, auroc: 0.9323, speedup_vs_v100: 2.27 },
];

/// Paper Sec. 4.3 parameter sweep sets.
pub const SWEEP_WIDTHS: &[usize] = &[1_000, 2_000, 5_000, 10_000, 20_000, 60_000];
pub const SWEEP_CHANNELS: &[usize] = &[1, 4, 8, 10, 15, 16, 32, 64];
pub const SWEEP_FILTERS: &[usize] = &[1, 4, 8, 10, 15, 16, 32, 64];
pub const SWEEP_FILTER_SIZES: &[usize] = &[1, 5, 9, 15, 21, 25, 31, 49, 51];
pub const SWEEP_DILATIONS: &[usize] = &[1, 2, 4, 8, 16];

/// Figure-4 family: C=15, K=15, d=8 on CLX, FP32, batch 56.
pub fn fig4_grid() -> Vec<(usize, usize, usize, usize, usize)> {
    // (c, k, q, s, d)
    let mut v = Vec::new();
    for &s in &[5usize, 9, 15, 21, 25, 31, 49, 51] {
        for &q in SWEEP_WIDTHS {
            v.push((15, 15, q, s, 8));
        }
    }
    v
}

/// Figure-5 family: C=64, K=64, d=1 (standard conv) on CLX, FP32.
pub fn fig5_grid() -> Vec<(usize, usize, usize, usize, usize)> {
    let mut v = Vec::new();
    for &s in &[5usize, 9, 15, 21, 25, 31, 49, 51] {
        for &q in SWEEP_WIDTHS {
            v.push((64, 64, q, s, 1));
        }
    }
    v
}

/// Figure-6 family: C=32, K=32, d=4 on CPX, BF16 vs FP32 baseline.
pub fn fig6_grid() -> Vec<(usize, usize, usize, usize, usize)> {
    let mut v = Vec::new();
    for &s in &[5usize, 9, 15, 21, 25, 31, 49, 51] {
        for &q in SWEEP_WIDTHS {
            v.push((32, 32, q, s, 4));
        }
    }
    v
}

/// Eq.-4 condition grid: crossing S and Q around the claimed boundary.
pub fn eq4_grid() -> Vec<(usize, usize, usize, usize, usize)> {
    let mut v = Vec::new();
    for &s in &[1usize, 3, 5, 9, 51] {
        for &q in &[200usize, 500, 1_000, 5_000, 20_000] {
            v.push((15, 15, q, s, 8));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_speedups_are_consistent() {
        assert!((table1_clx_speedup() - 6.86).abs() < 0.01);
        for row in TABLE2 {
            if row.device == "8 V100" {
                continue;
            }
            let implied = 162.0 / row.time_per_epoch;
            assert!(
                (implied - row.speedup_vs_v100).abs() < 0.015,
                "{}: implied {implied} vs published {}",
                row.device,
                row.speedup_vs_v100
            );
        }
    }

    #[test]
    fn grids_cover_paper_corners() {
        let f4 = fig4_grid();
        assert!(f4.contains(&(15, 15, 60_000, 51, 8)));
        let f5 = fig5_grid();
        assert!(f5.contains(&(64, 64, 1_000, 5, 1)));
        let f6 = fig6_grid();
        assert!(f6.iter().all(|&(c, k, _, _, d)| c == 32 && k == 32 && d == 4));
        assert_eq!(f4.len(), 48);
    }
}
