//! Checkpointing: save/load the flat parameter vector in a tiny
//! self-describing binary format (magic + version + length + LE f32 data
//! + xor checksum). Interoperates with both the native and PJRT paths,
//! which share the flat packing order.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"DC1D";
const VERSION: u32 = 1;

fn checksum(data: &[f32]) -> u32 {
    let mut x = 0xDEAD_BEEFu32;
    for v in data {
        x ^= v.to_bits();
        x = x.rotate_left(7);
    }
    x
}

/// Save a flat parameter vector.
pub fn save(path: impl AsRef<Path>, params: &[f32]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    f.write_all(&checksum(params).to_le_bytes())?;
    let mut buf = Vec::with_capacity(params.len() * 4);
    for v in params {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Load a flat parameter vector, validating magic/version/checksum.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
    let mut head = [0u8; 4 + 4 + 8 + 4];
    f.read_exact(&mut head).context("reading header")?;
    if &head[0..4] != MAGIC {
        bail!("not a dilconv1d checkpoint (bad magic)");
    }
    let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let len = u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize;
    let want_sum = u32::from_le_bytes(head[16..20].try_into().unwrap());
    let mut buf = vec![0u8; len * 4];
    f.read_exact(&mut buf).context("reading parameters")?;
    let params: Vec<f32> = buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    if checksum(&params) != want_sum {
        bail!("checkpoint checksum mismatch (corrupt file)");
    }
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("dilconv_ckpt_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt.ckpt");
        let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save(&p, &params).unwrap();
        assert_eq!(load(&p).unwrap(), params);
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt.ckpt");
        save(&p, &[1.0, 2.0, 3.0]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF;
        std::fs::write(&p, bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("checksum"));
    }

    #[test]
    fn rejects_wrong_magic() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOPE0000000000000000000000").unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn empty_params_roundtrip() {
        let p = tmp("empty.ckpt");
        save(&p, &[]).unwrap();
        assert_eq!(load(&p).unwrap(), Vec::<f32>::new());
    }
}
