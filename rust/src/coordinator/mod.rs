//! Training coordinator: the epoch loop with simulated multi-socket data
//! parallelism ([`trainer`]), checkpointing ([`checkpoint`]) and the
//! paper-experiment descriptors ([`experiment`]).

pub mod checkpoint;
pub mod experiment;
pub mod trainer;

pub use trainer::{EpochReport, Trainer};
