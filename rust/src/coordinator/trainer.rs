//! The training coordinator: epoch loop over the native engine with
//! simulated multi-socket data parallelism (paper Sec. 4.4/4.5).
//!
//! One step:
//!   1. the loader thread delivers a global batch (DataLoader-worker analog),
//!   2. the batch is sharded across `sockets` replicas,
//!   3. each replica computes gradients on its shard (scoped thread),
//!   4. gradients are ring-all-reduced (the real algorithm from dist/),
//!   5. the Adam step is applied and parameters broadcast to all replicas.
//!
//! Per-epoch evaluation computes MSE + AUROC on the validation split
//! (paper Table 1's metrics). Timing is recorded separately for train and
//! eval, as in paper Fig. 10.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::atacseq::TrackConfig;
use crate::data::{Dataset, Loader};
use crate::dist::allreduce::ring_allreduce;
use crate::dist::comm_model::CommModel;
use crate::metrics::auroc::AurocAccumulator;
use crate::metrics::regression::MseAccumulator;
use crate::metrics::timing::{EpochTiming, Timer};
use crate::model::{Adam, AtacWorksNet, NetConfig, Tensor};

/// Per-epoch results.
#[derive(Debug, Clone, Copy)]
pub struct EpochReport {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_mse: f64,
    pub train_bce: f64,
    pub val_mse: f64,
    pub val_auroc: Option<f64>,
    pub timing: EpochTiming,
    /// Modelled multi-socket communication time (α–β ring model).
    pub modeled_comm_secs: f64,
    pub steps: usize,
}

/// The coordinator.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub track_cfg: TrackConfig,
    pub dataset: Dataset,
    replicas: Vec<AtacWorksNet>,
    opt: Adam,
    params: Vec<f32>,
    comm: CommModel,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        let net_cfg = NetConfig {
            channels: cfg.channels,
            n_blocks: cfg.n_blocks,
            filter_size: cfg.filter_size,
            dilation: cfg.dilation,
        };
        let track_cfg = TrackConfig {
            width: cfg.segment_width,
            pad: cfg.segment_pad,
            ..TrackConfig::default()
        };
        // The network's epilogue shape is fixed by its topology: every
        // layer fuses a bias, block tails fuse the residual add. The
        // config's post_ops therefore selects the *body activation* only
        // — reject specs this network cannot honor instead of silently
        // dropping components (e.g. "none" would strip every bias).
        if !cfg.post_ops.bias || cfg.post_ops.residual || cfg.post_ops.scale != 1.0 {
            return Err(anyhow::anyhow!(
                "post_ops = \"{}\" is not trainable: the AtacWorks network always fuses \
                 bias (+ residual on block tails, fixed by topology); use \"bias\", \
                 \"bias_relu\" or \"bias_sigmoid\"",
                cfg.post_ops
            ));
        }
        // Config validated — now warm-start the autotuner from a persisted tuning table
        // before any plan is built, so the first epoch already uses the
        // previously-measured winners.
        if cfg.autotune {
            if let Some(path) = cfg.tune_cache.as_deref() {
                if std::path::Path::new(path).exists() {
                    match crate::conv1d::autotuner().load(path) {
                        Ok(n) => println!("autotuner: warm-started {n} entries from {path}"),
                        Err(e) => eprintln!("warning: ignoring tune cache: {e}"),
                    }
                }
            }
        }
        let mut replicas: Vec<AtacWorksNet> = (0..cfg.sockets.max(1))
            .map(|_| AtacWorksNet::init(net_cfg, cfg.seed))
            .collect();
        for r in &mut replicas {
            r.set_backend(cfg.backend, cfg.threads_per_socket);
            r.set_precision(cfg.precision);
            r.set_autotune(cfg.autotune);
            r.set_activation(cfg.post_ops.activation);
        }
        let params = replicas[0].pack_params();
        let opt = Adam::new(params.len(), cfg.lr as f32);
        let dataset = Dataset::with_train_size(cfg.seed, cfg.train_segments);
        Ok(Trainer {
            cfg,
            track_cfg,
            dataset,
            replicas,
            opt,
            params,
            comm: CommModel::upi(),
        })
    }

    /// Flat parameter vector (packing order shared with the PJRT path).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    /// Load parameters (e.g. from a checkpoint).
    pub fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.params.len());
        for r in &mut self.replicas {
            r.unpack_params(&params);
        }
        self.params = params;
    }

    /// Run one training epoch (+ validation) and report.
    pub fn run_epoch(&mut self, epoch: usize) -> EpochReport {
        let order = self.dataset.epoch_order(epoch as u64);
        let global_batch = self.cfg.batch_size.max(self.cfg.sockets);
        let mut loader = Loader::spawn(
            self.track_cfg,
            self.cfg.seed,
            order,
            global_batch,
            2,
        );
        let wp = self.track_cfg.padded_width();
        let sockets = self.cfg.sockets.max(1);
        let t_train = Timer::start();
        let mut comm_secs_modeled = 0.0;
        let (mut sum_loss, mut sum_mse, mut sum_bce) = (0.0f64, 0.0f64, 0.0f64);
        let mut steps = 0usize;
        while let Some(batch) = loader.next_batch() {
            // Shard the batch across socket replicas.
            let rows_per = batch.n / sockets;
            if rows_per == 0 {
                continue;
            }
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(sockets);
            let mut losses = vec![(0.0f64, 0.0f64, 0.0f64); sockets];
            {
                let mut slots: Vec<Option<Vec<f32>>> = (0..sockets).map(|_| None).collect();
                std::thread::scope(|scope| {
                    for (rank, (net, (slot, lrec))) in self
                        .replicas
                        .iter_mut()
                        .zip(slots.iter_mut().zip(losses.iter_mut()))
                        .enumerate()
                    {
                        let lo = rank * rows_per;
                        let hi = lo + rows_per;
                        let x = Tensor::from_vec(
                            batch.x[lo * wp..hi * wp].to_vec(),
                            rows_per,
                            1,
                            wp,
                        );
                        let clean = Tensor::from_vec(
                            batch.clean[lo * wp..hi * wp].to_vec(),
                            rows_per,
                            1,
                            wp,
                        );
                        let peaks = Tensor::from_vec(
                            batch.peaks[lo * wp..hi * wp].to_vec(),
                            rows_per,
                            1,
                            wp,
                        );
                        scope.spawn(move || {
                            let (g, l) = net.forward_backward(&x, &clean, &peaks);
                            *slot = Some(net.pack_grads(&g));
                            *lrec = (l.total, l.mse, l.bce);
                        });
                    }
                });
                for slot in slots {
                    grads.push(slot.expect("replica produced no gradient"));
                }
            }
            // Gradient synchronisation: real ring all-reduce + α–β model of
            // what it would cost between the paper's sockets.
            ring_allreduce(&mut grads);
            comm_secs_modeled += self.comm.ring_allreduce_secs(self.params.len(), sockets);
            let mut grad = grads.swap_remove(0);
            let inv = 1.0 / sockets as f32;
            for g in grad.iter_mut() {
                *g *= inv;
            }
            self.opt.step(&mut self.params, &grad);
            for r in &mut self.replicas {
                r.unpack_params(&self.params);
            }
            let (lt, lm, lb) = losses
                .iter()
                .fold((0.0, 0.0, 0.0), |a, l| (a.0 + l.0, a.1 + l.1, a.2 + l.2));
            sum_loss += lt / sockets as f64;
            sum_mse += lm / sockets as f64;
            sum_bce += lb / sockets as f64;
            steps += 1;
        }
        let train_secs = t_train.elapsed_secs();

        // Validation (paper holds out chr20).
        let t_eval = Timer::start();
        let (val_mse, val_auroc) = self.evaluate(32);
        let eval_secs = t_eval.elapsed_secs();

        let d = steps.max(1) as f64;
        EpochReport {
            epoch,
            train_loss: sum_loss / d,
            train_mse: sum_mse / d,
            train_bce: sum_bce / d,
            val_mse,
            val_auroc,
            timing: EpochTiming {
                train_secs,
                eval_secs,
                data_secs: 0.0,
                comm_secs: comm_secs_modeled,
            },
            modeled_comm_secs: comm_secs_modeled,
            steps,
        }
    }

    /// Evaluate MSE + AUROC on (up to `max_segments` of) the validation
    /// split using replica 0.
    pub fn evaluate(&mut self, max_segments: usize) -> (f64, Option<f64>) {
        let wp = self.track_cfg.padded_width();
        let val: Vec<u64> = self
            .dataset
            .validation
            .iter()
            .copied()
            .take(max_segments)
            .collect();
        if val.is_empty() {
            return (0.0, None);
        }
        let mut mse_acc = MseAccumulator::new();
        let mut auroc_acc = AurocAccumulator::new();
        let stride = (wp / 2_000).max(1);
        for chunk in val.chunks(4) {
            let b = crate::data::make_batch(&self.track_cfg, self.cfg.seed, chunk);
            let x = Tensor::from_vec(b.x, chunk.len(), 1, wp);
            let (den, logits, _) = self.replicas[0].forward(&x, false);
            mse_acc.push(&den.data, &b.clean);
            auroc_acc.push_strided(&logits.data, &b.peaks, stride);
        }
        (mse_acc.compute(), auroc_acc.compute())
    }

    /// Train for `cfg.epochs` epochs, invoking `on_epoch` after each.
    /// With `autotune` + `tune_cache` set, the tuning table is persisted
    /// when training finishes so the next run warm-starts.
    pub fn train(&mut self, mut on_epoch: impl FnMut(&EpochReport)) -> Vec<EpochReport> {
        let mut reports = Vec::with_capacity(self.cfg.epochs);
        for e in 0..self.cfg.epochs {
            let r = self.run_epoch(e);
            on_epoch(&r);
            reports.push(r);
        }
        if self.cfg.autotune {
            if let Some(path) = self.cfg.tune_cache.as_deref() {
                if let Err(e) = crate::conv1d::autotuner().save(path) {
                    eprintln!("warning: could not persist tune cache to {path}: {e}");
                }
            }
        }
        reports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            channels: 4,
            n_blocks: 1,
            filter_size: 9,
            dilation: 2,
            segment_width: 400,
            segment_pad: 40,
            train_segments: 8,
            batch_size: 2,
            epochs: 2,
            lr: 1e-3,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn unsupported_post_ops_are_rejected() {
        use crate::conv1d::PostOps;
        let mut cfg = tiny_cfg();
        cfg.post_ops = PostOps::none();
        assert!(Trainer::new(cfg).is_err(), "post_ops none must be rejected");
        let mut cfg = tiny_cfg();
        cfg.post_ops = PostOps::parse("bias_sigmoid").unwrap();
        assert!(Trainer::new(cfg).is_ok());
    }

    #[test]
    fn trains_and_loss_decreases() {
        let mut t = Trainer::new(tiny_cfg()).unwrap();
        let reports = t.train(|_| {});
        assert_eq!(reports.len(), 2);
        assert!(reports[0].steps > 0);
        assert!(
            reports[1].train_loss < reports[0].train_loss,
            "{} -> {}",
            reports[0].train_loss,
            reports[1].train_loss
        );
        assert!(reports[1].val_auroc.is_some());
    }

    #[test]
    fn multisocket_matches_single_socket_losses() {
        // Data-parallel with P sockets over the same global batch must
        // produce the same parameter trajectory as 1 socket (deterministic
        // data, averaged gradients ≈ full-batch gradient).
        let mut c1 = tiny_cfg();
        c1.epochs = 1;
        let mut c2 = c1.clone();
        c2.sockets = 2;
        let mut t1 = Trainer::new(c1).unwrap();
        let mut t2 = Trainer::new(c2).unwrap();
        let r1 = t1.run_epoch(0);
        let r2 = t2.run_epoch(0);
        assert_eq!(r1.steps, r2.steps);
        // Same global batches, gradient averaging == concatenated batch mean
        // (both loss terms are means over the batch rows).
        for (a, b) in t1.params().iter().zip(t2.params()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(r2.modeled_comm_secs > 0.0);
        assert_eq!(r1.modeled_comm_secs, 0.0);
    }
}
