//! The training coordinator: epoch loop over the native engine with
//! simulated multi-socket data parallelism (paper Sec. 4.4/4.5 and
//! DESIGN.md §6).
//!
//! One step:
//!   1. the loader thread delivers a global batch (DataLoader-worker analog),
//!   2. the batch is sharded across `sockets` replicas,
//!   3. each replica computes gradients on its shard — on a **persistent
//!      worker pool** (one long-lived thread per socket owning its
//!      replica; no per-step thread spawns),
//!   4. gradients are all-reduced — either monolithically after the
//!      whole backward, or (with `overlap = true`) **bucket by bucket as
//!      each layer's backward completes**, overlapping communication with
//!      compute; the bucketed reduction is bit-identical to the
//!      monolithic one (chunking follows the global grid). On a
//!      multi-socket machine ([`Topology::detect`]) the collective takes
//!      the NUMA-hierarchical path, which reproduces the flat ring's
//!      accumulation order exactly (DESIGN.md §6b) — placement is a
//!      performance knob, never a numerics one,
//!   5. the split Adam step updates the FP32 master weights and the
//!      replicas reload the (bf16-rounded under `precision = bf16`)
//!      working copy at the start of the next step.
//!
//! Per-epoch evaluation computes MSE + AUROC on the validation split
//! (paper Table 1's metrics). Timing is recorded separately for train and
//! eval, as in paper Fig. 10.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::atacseq::{Batch, TrackConfig};
use crate::data::{Dataset, Loader};
use crate::dist::allreduce::{hierarchical_allreduce, hierarchical_allreduce_aligned};
use crate::dist::comm_model::CommModel;
use crate::dist::{BucketPlan, PersistentPool, Topology};
use crate::metrics::auroc::AurocAccumulator;
use crate::metrics::regression::MseAccumulator;
use crate::metrics::timing::{EpochTiming, Timer};
use crate::model::{Adam, AtacWorksNet, MasterWeights, NetConfig, Tensor};

/// `(total, mse, bce)` of one replica's step.
type LossTriple = (f64, f64, f64);

/// Per-epoch results.
#[derive(Debug, Clone, Copy)]
pub struct EpochReport {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_mse: f64,
    pub train_bce: f64,
    pub val_mse: f64,
    pub val_auroc: Option<f64>,
    pub timing: EpochTiming,
    /// Modelled multi-socket communication time (α–β ring model),
    /// **serialized**: the full cost of every collective, as if none of
    /// it were hidden behind compute.
    pub modeled_comm_secs: f64,
    /// The part of [`Self::modeled_comm_secs`] the α–β timeline says
    /// would actually extend the step on the paper's links: with the
    /// bucketed, backward-overlapped all-reduce most of the collective
    /// hides behind compute, so `exposed < modeled`; on the monolithic
    /// path nothing overlaps and the two are equal.
    pub exposed_comm_secs: f64,
    pub steps: usize,
}

/// Gradient + bookkeeping of one synchronous data-parallel step.
struct StepOutcome {
    /// Rank-0 copy of the all-reduced (summed, not yet averaged) gradient.
    grad: Vec<f32>,
    losses: Vec<LossTriple>,
    comm_secs: f64,
    exposed_secs: f64,
}

/// The coordinator.
///
/// ```
/// use dilconv1d::config::TrainConfig;
/// use dilconv1d::coordinator::Trainer;
///
/// // A toy run: 5 conv layers, 2 in-process sockets, bucketed
/// // backward-overlapped all-reduce (bit-identical to monolithic).
/// let cfg = TrainConfig {
///     channels: 2,
///     n_blocks: 1,
///     filter_size: 5,
///     dilation: 1,
///     segment_width: 120,
///     segment_pad: 12,
///     train_segments: 2,
///     batch_size: 2,
///     epochs: 1,
///     sockets: 2,
///     overlap: true,
///     ..TrainConfig::default()
/// };
/// let mut trainer = Trainer::new(cfg).unwrap();
/// let report = trainer.run_epoch(0);
/// assert!(report.steps > 0);
/// // Overlap can only hide communication, never add to it.
/// assert!(report.exposed_comm_secs <= report.modeled_comm_secs);
/// ```
pub struct Trainer {
    pub cfg: TrainConfig,
    pub track_cfg: TrackConfig,
    pub dataset: Dataset,
    /// Persistent data-parallel pool: thread `r` owns replica `r`.
    pool: PersistentPool<AtacWorksNet>,
    opt: Adam,
    /// FP32 master weights + the working copy the replicas load
    /// (bf16-rounded under `precision = bf16` — split Adam).
    weights: MasterWeights,
    /// Gradient bucket partition (backward completion order); `Some` iff
    /// `cfg.overlap`.
    buckets: Option<Arc<BucketPlan>>,
    comm: CommModel,
}

impl Trainer {
    /// Build a trainer on the detected machine shape: replicas are
    /// placed across the NUMA sockets [`Topology::detect`] reports
    /// (`CONV1D_TOPOLOGY` override) and gradient collectives take the
    /// hierarchical path when there is more than one.
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        let topo = Topology::detect();
        Self::with_topology(cfg, topo)
    }

    /// [`Self::new`] with an explicit machine shape — what tests and the
    /// benches use to pin the placement without touching the
    /// environment.
    pub fn with_topology(cfg: TrainConfig, topo: Topology) -> Result<Trainer> {
        let net_cfg = NetConfig {
            channels: cfg.channels,
            n_blocks: cfg.n_blocks,
            filter_size: cfg.filter_size,
            dilation: cfg.dilation,
        };
        let track_cfg = TrackConfig {
            width: cfg.segment_width,
            pad: cfg.segment_pad,
            ..TrackConfig::default()
        };
        // The network's epilogue shape is fixed by its topology: every
        // layer fuses a bias, block tails fuse the residual add. The
        // config's post_ops therefore selects the *body activation* only
        // — reject specs this network cannot honor instead of silently
        // dropping components (e.g. "none" would strip every bias).
        if !cfg.post_ops.bias || cfg.post_ops.residual || cfg.post_ops.scale != 1.0 {
            return Err(anyhow::anyhow!(
                "post_ops = \"{}\" is not trainable: the AtacWorks network always fuses \
                 bias (+ residual on block tails, fixed by topology); use \"bias\", \
                 \"bias_relu\" or \"bias_sigmoid\"",
                cfg.post_ops
            ));
        }
        // Config validated — now warm-start the autotuner from a persisted tuning table
        // before any plan is built, so the first epoch already uses the
        // previously-measured winners.
        if cfg.autotune {
            if let Some(path) = cfg.tune_cache.as_deref() {
                if std::path::Path::new(path).exists() {
                    match crate::conv1d::autotuner().load(path) {
                        Ok(n) => println!("autotuner: warm-started {n} entries from {path}"),
                        Err(e) => eprintln!("warning: ignoring tune cache: {e}"),
                    }
                }
            }
        }
        // Replica construction is deterministic in `(net_cfg, seed)`, so
        // a local prototype supplies the initial master weights while the
        // pool builds each replica **on its own rank thread** — placed
        // across the machine's sockets, its state first-touched by the
        // socket group that computes with it.
        let weights = MasterWeights::new(
            AtacWorksNet::init(net_cfg, cfg.seed).pack_params(),
            cfg.precision,
        );
        let opt = Adam::new(weights.len(), cfg.lr as f32);
        let placement = topo.placement(cfg.sockets.max(1));
        let (backend, threads, partition, precision, autotune, activation, seed) = (
            cfg.backend,
            cfg.threads_per_socket,
            cfg.partition,
            cfg.precision,
            cfg.autotune,
            cfg.post_ops.activation,
            cfg.seed,
        );
        let pool = PersistentPool::new_placed(placement, move |_rank, _socket| {
            let mut net = AtacWorksNet::init(net_cfg, seed);
            net.set_backend(backend, threads);
            net.set_partition(partition);
            net.set_precision(precision);
            net.set_autotune(autotune);
            net.set_activation(activation);
            net
        });
        let buckets = cfg.overlap.then(|| {
            Arc::new(BucketPlan::new(
                &net_cfg.layer_param_counts(),
                &net_cfg.backward_completion_order(),
                cfg.bucket_bytes(),
            ))
        });
        let dataset = Dataset::with_train_size(cfg.seed, cfg.train_segments);
        Ok(Trainer {
            cfg,
            track_cfg,
            dataset,
            pool,
            opt,
            weights,
            buckets,
            comm: CommModel::upi(),
        })
    }

    /// FP32 master parameter vector (packing order shared with the PJRT
    /// path; what checkpoints store).
    pub fn params(&self) -> &[f32] {
        self.weights.master()
    }

    /// The working copy the replicas compute with: bf16-rounded under
    /// `precision = bf16`, identical to [`Self::params`] under f32.
    pub fn working_params(&self) -> &[f32] {
        self.weights.working()
    }

    pub fn param_count(&self) -> usize {
        self.weights.len()
    }

    /// Load parameters (e.g. from a checkpoint) into the master copy; the
    /// replicas pick up the refreshed working copy on their next job.
    pub fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.weights.len());
        self.weights.set_master(&params);
    }

    /// Shard `batch` and return rank `rank`'s `(x, clean, peaks)`.
    fn shard(batch: &Batch, rank: usize, rows_per: usize, wp: usize) -> (Tensor, Tensor, Tensor) {
        let lo = rank * rows_per;
        let hi = lo + rows_per;
        (
            Tensor::from_vec(batch.x[lo * wp..hi * wp].to_vec(), rows_per, 1, wp),
            Tensor::from_vec(batch.clean[lo * wp..hi * wp].to_vec(), rows_per, 1, wp),
            Tensor::from_vec(batch.peaks[lo * wp..hi * wp].to_vec(), rows_per, 1, wp),
        )
    }

    /// One synchronous step, monolithic flavour: every rank runs its full
    /// backward, then one ring all-reduce over the whole gradient. The
    /// modeled collective is priced at `param_count` elements — the α–β
    /// model shards the message across the ring internally
    /// (`ring_bytes_per_rank` divides by the rank count), so passing the
    /// full gradient length here is correct; an audit for a suspected
    /// double-count of the per-replica shard found none.
    fn step_monolithic(&self, batch: &Batch, rows_per: usize, wp: usize) -> StepOutcome {
        let sockets = self.pool.ranks();
        let params = Arc::new(self.weights.working().to_vec());
        let (tx, rx) = mpsc::channel::<(usize, Vec<f32>, LossTriple)>();
        for rank in 0..sockets {
            let (x, clean, peaks) = Self::shard(batch, rank, rows_per, wp);
            let tx = tx.clone();
            let params = Arc::clone(&params);
            self.pool.exec(rank, move |net| {
                net.unpack_params(&params);
                let (grads, l) = net.forward_backward(&x, &clean, &peaks);
                let flat = net.pack_grads(&grads);
                let _ = tx.send((rank, flat, (l.total, l.mse, l.bce)));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Vec<f32>>> = (0..sockets).map(|_| None).collect();
        let mut losses = vec![(0.0, 0.0, 0.0); sockets];
        for _ in 0..sockets {
            let (rank, flat, l) = rx.recv().expect("replica worker died");
            slots[rank] = Some(flat);
            losses[rank] = l;
        }
        let mut grads: Vec<Vec<f32>> = slots
            .into_iter()
            .map(|s| s.expect("every rank reports"))
            .collect();
        // NUMA-hierarchical on a placed pool, plain ring on a flat one —
        // bit-identical either way (the hierarchical path reproduces the
        // ring's per-chunk accumulation order, DESIGN.md §6b).
        hierarchical_allreduce(&mut grads, self.pool.placement());
        let comm = self.comm.ring_allreduce_secs(self.weights.len(), sockets);
        StepOutcome {
            grad: grads.swap_remove(0),
            losses,
            comm_secs: comm,
            // Monolithic: the collective runs strictly after backward —
            // all of it is exposed.
            exposed_secs: comm,
        }
    }

    /// One synchronous step, bucketed + overlapped flavour: each rank's
    /// backward streams per-layer gradients into completion-ordered
    /// buckets and ships every bucket the moment its last layer is done;
    /// this (main) thread plays the communication channel, reducing each
    /// bucket while the ranks differentiate earlier layers. The aligned
    /// ring keeps the result bit-identical to `step_monolithic`.
    fn step_bucketed(&self, batch: &Batch, rows_per: usize, wp: usize) -> StepOutcome {
        let plan = self
            .buckets
            .as_ref()
            .expect("bucketed step requires a bucket plan")
            .clone();
        let sockets = self.pool.ranks();
        let n_buckets = plan.n_buckets();
        let total = self.weights.len();
        let params = Arc::new(self.weights.working().to_vec());
        let t0 = Instant::now();
        let (gtx, grx) = mpsc::channel::<(usize, usize, Vec<f32>, f64)>();
        let (ltx, lrx) = mpsc::channel::<(usize, LossTriple)>();
        for rank in 0..sockets {
            let (x, clean, peaks) = Self::shard(batch, rank, rows_per, wp);
            let gtx = gtx.clone();
            let ltx = ltx.clone();
            let params = Arc::clone(&params);
            let plan = Arc::clone(&plan);
            self.pool.exec(rank, move |net| {
                net.unpack_params(&params);
                let mut bufs: Vec<Option<Vec<f32>>> = (0..plan.n_buckets())
                    .map(|b| Some(vec![0.0f32; plan.bucket_elems(b)]))
                    .collect();
                let mut left = plan.layers_per_bucket();
                let l = net.forward_backward_streaming(&x, &clean, &peaks, |layer, grads| {
                    let (b, off) = plan.slot(layer);
                    let buf = bufs[b].as_mut().expect("bucket already shipped");
                    let wl = grads.w.len();
                    buf[off..off + wl].copy_from_slice(&grads.w);
                    buf[off + wl..off + wl + grads.b.len()].copy_from_slice(&grads.b);
                    left[b] -= 1;
                    if left[b] == 0 {
                        let buf = bufs[b].take().expect("bucket shipped twice");
                        let _ = gtx.send((b, rank, buf, t0.elapsed().as_secs_f64()));
                    }
                });
                let _ = ltx.send((rank, (l.total, l.mse, l.bce)));
            });
        }
        drop(gtx);
        drop(ltx);
        // Communication channel: reduce each bucket as soon as all ranks
        // have shipped it — while later (earlier-layer) buckets are still
        // being computed.
        let mut flat = vec![0.0f32; total];
        let mut pending: Vec<Vec<Option<Vec<f32>>>> = (0..n_buckets)
            .map(|_| (0..sockets).map(|_| None).collect())
            .collect();
        let mut arrived = vec![0usize; n_buckets];
        let mut ready_secs = vec![0.0f64; n_buckets];
        let mut reduced = 0usize;
        while reduced < n_buckets {
            let (b, rank, buf, t) = grx.recv().expect("bucketed backward worker died");
            assert!(pending[b][rank].is_none(), "bucket {b} from rank {rank} twice");
            pending[b][rank] = Some(buf);
            ready_secs[b] = ready_secs[b].max(t);
            arrived[b] += 1;
            if arrived[b] == sockets {
                let mut bufs: Vec<Vec<f32>> = pending[b]
                    .iter_mut()
                    .map(|s| s.take().expect("every rank shipped bucket"))
                    .collect();
                hierarchical_allreduce_aligned(
                    &mut bufs,
                    &plan.bucket(b).regions,
                    total,
                    self.pool.placement(),
                );
                plan.scatter(b, &bufs[0], &mut flat);
                reduced += 1;
            }
        }
        let mut losses = vec![(0.0, 0.0, 0.0); sockets];
        for _ in 0..sockets {
            let (rank, l) = lrx.recv().expect("replica worker died");
            losses[rank] = l;
        }
        // Price the same timeline on the paper's links: per-bucket ring
        // costs against the measured ready times.
        let report = self
            .comm
            .bucketed_overlap(&plan.elems_per_bucket(), sockets, &ready_secs);
        StepOutcome {
            grad: flat,
            losses,
            comm_secs: report.comm_secs,
            exposed_secs: report.exposed_secs,
        }
    }

    /// Run one training epoch (+ validation) and report.
    pub fn run_epoch(&mut self, epoch: usize) -> EpochReport {
        let order = self.dataset.epoch_order(epoch as u64);
        let global_batch = self.cfg.batch_size.max(self.cfg.sockets);
        let mut loader = Loader::spawn(self.track_cfg, self.cfg.seed, order, global_batch, 2);
        let wp = self.track_cfg.padded_width();
        let sockets = self.pool.ranks();
        let t_train = Timer::start();
        let mut comm_secs = 0.0;
        let mut exposed_secs = 0.0;
        let (mut sum_loss, mut sum_mse, mut sum_bce) = (0.0f64, 0.0f64, 0.0f64);
        let mut steps = 0usize;
        while let Some(batch) = loader.next_batch() {
            // Shard the batch across socket replicas.
            let rows_per = batch.n / sockets;
            if rows_per == 0 {
                continue;
            }
            let outcome = if self.cfg.overlap {
                self.step_bucketed(&batch, rows_per, wp)
            } else {
                self.step_monolithic(&batch, rows_per, wp)
            };
            comm_secs += outcome.comm_secs;
            exposed_secs += outcome.exposed_secs;
            let mut grad = outcome.grad;
            let inv = 1.0 / sockets as f32;
            for g in grad.iter_mut() {
                *g *= inv;
            }
            // Split optimizer step: FP32 update on the master, bf16
            // re-round into the working copy the replicas load next step.
            let opt = &mut self.opt;
            self.weights.update(|master| opt.step(master, &grad));
            let (lt, lm, lb) = outcome
                .losses
                .iter()
                .fold((0.0, 0.0, 0.0), |a, l| (a.0 + l.0, a.1 + l.1, a.2 + l.2));
            sum_loss += lt / sockets as f64;
            sum_mse += lm / sockets as f64;
            sum_bce += lb / sockets as f64;
            steps += 1;
        }
        let train_secs = t_train.elapsed_secs();

        // Validation (paper holds out chr20).
        let t_eval = Timer::start();
        let (val_mse, val_auroc) = self.evaluate(32);
        let eval_secs = t_eval.elapsed_secs();

        let d = steps.max(1) as f64;
        EpochReport {
            epoch,
            train_loss: sum_loss / d,
            train_mse: sum_mse / d,
            train_bce: sum_bce / d,
            val_mse,
            val_auroc,
            timing: EpochTiming {
                train_secs,
                eval_secs,
                data_secs: 0.0,
                comm_secs,
            },
            modeled_comm_secs: comm_secs,
            exposed_comm_secs: exposed_secs,
            steps,
        }
    }

    /// Evaluate MSE + AUROC on (up to `max_segments` of) the validation
    /// split using replica 0 (on its own pool thread, with the current
    /// working parameters).
    pub fn evaluate(&mut self, max_segments: usize) -> (f64, Option<f64>) {
        let wp = self.track_cfg.padded_width();
        let val: Vec<u64> = self
            .dataset
            .validation
            .iter()
            .copied()
            .take(max_segments)
            .collect();
        if val.is_empty() {
            return (0.0, None);
        }
        let track = self.track_cfg;
        let seed = self.cfg.seed;
        let stride = (wp / 2_000).max(1);
        let params = Arc::new(self.weights.working().to_vec());
        let (tx, rx) = mpsc::channel::<(f64, Option<f64>)>();
        self.pool.exec(0, move |net| {
            net.unpack_params(&params);
            let mut mse_acc = MseAccumulator::new();
            let mut auroc_acc = AurocAccumulator::new();
            for chunk in val.chunks(4) {
                let b = crate::data::make_batch(&track, seed, chunk);
                let x = Tensor::from_vec(b.x, chunk.len(), 1, wp);
                let (den, logits, _) = net.forward(&x, false);
                mse_acc.push(&den.data, &b.clean);
                auroc_acc.push_strided(&logits.data, &b.peaks, stride);
            }
            let _ = tx.send((mse_acc.compute(), auroc_acc.compute()));
        });
        rx.recv().expect("evaluation worker died")
    }

    /// Train for `cfg.epochs` epochs, invoking `on_epoch` after each.
    /// With `autotune` + `tune_cache` set, the tuning table is persisted
    /// when training finishes so the next run warm-starts.
    pub fn train(&mut self, mut on_epoch: impl FnMut(&EpochReport)) -> Vec<EpochReport> {
        let mut reports = Vec::with_capacity(self.cfg.epochs);
        for e in 0..self.cfg.epochs {
            let r = self.run_epoch(e);
            on_epoch(&r);
            reports.push(r);
        }
        if self.cfg.autotune {
            if let Some(path) = self.cfg.tune_cache.as_deref() {
                if let Err(e) = crate::conv1d::autotuner().save(path) {
                    eprintln!("warning: could not persist tune cache to {path}: {e}");
                }
            }
        }
        reports
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::Precision;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            channels: 4,
            n_blocks: 1,
            filter_size: 9,
            dilation: 2,
            segment_width: 400,
            segment_pad: 40,
            train_segments: 8,
            batch_size: 2,
            epochs: 2,
            lr: 1e-3,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn unsupported_post_ops_are_rejected() {
        use crate::conv1d::PostOps;
        let mut cfg = tiny_cfg();
        cfg.post_ops = PostOps::none();
        assert!(Trainer::new(cfg).is_err(), "post_ops none must be rejected");
        let mut cfg = tiny_cfg();
        cfg.post_ops = PostOps::parse("bias_sigmoid").unwrap();
        assert!(Trainer::new(cfg).is_ok());
    }

    #[test]
    fn trains_and_loss_decreases() {
        let mut t = Trainer::new(tiny_cfg()).unwrap();
        let reports = t.train(|_| {});
        assert_eq!(reports.len(), 2);
        assert!(reports[0].steps > 0);
        assert!(
            reports[1].train_loss < reports[0].train_loss,
            "{} -> {}",
            reports[0].train_loss,
            reports[1].train_loss
        );
        assert!(reports[1].val_auroc.is_some());
    }

    #[test]
    fn multisocket_matches_single_socket_losses() {
        // Data-parallel with P sockets over the same global batch must
        // produce the same parameter trajectory as 1 socket (deterministic
        // data, averaged gradients ≈ full-batch gradient).
        let mut c1 = tiny_cfg();
        c1.epochs = 1;
        let mut c2 = c1.clone();
        c2.sockets = 2;
        let mut t1 = Trainer::new(c1).unwrap();
        let mut t2 = Trainer::new(c2).unwrap();
        let r1 = t1.run_epoch(0);
        let r2 = t2.run_epoch(0);
        assert_eq!(r1.steps, r2.steps);
        // Same global batches, gradient averaging == concatenated batch mean
        // (both loss terms are means over the batch rows).
        for (a, b) in t1.params().iter().zip(t2.params()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(r2.modeled_comm_secs > 0.0);
        assert_eq!(r1.modeled_comm_secs, 0.0);
        // Monolithic path: nothing overlaps, all of it is exposed.
        assert_eq!(r2.exposed_comm_secs, r2.modeled_comm_secs);
    }

    #[test]
    fn numa_placed_training_is_bit_identical_to_flat() {
        // The hierarchical all-reduce reproduces the flat ring's
        // accumulation order, so the parameter trajectory must match
        // bit for bit at every emulated machine shape — monolithic and
        // bucketed/overlapped alike.
        for overlap in [false, true] {
            let mut base = tiny_cfg();
            base.epochs = 1;
            base.sockets = 4;
            base.overlap = overlap;
            let mut flat = Trainer::with_topology(base.clone(), Topology::shape(1, 8)).unwrap();
            let r_flat = flat.run_epoch(0);
            for topo in [Topology::shape(2, 4), Topology::shape(4, 2)] {
                let mut placed = Trainer::with_topology(base.clone(), topo).unwrap();
                let r = placed.run_epoch(0);
                assert_eq!(r.steps, r_flat.steps);
                for (i, (a, b)) in flat.params().iter().zip(placed.params()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "param {i} diverged under {topo} (overlap={overlap}): {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bf16_training_keeps_fp32_master_and_bf16_working_copies() {
        use crate::conv1d::bf16::Bf16;
        let mut cfg = tiny_cfg();
        cfg.epochs = 1;
        cfg.precision = Precision::Bf16;
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run_epoch(0);
        assert!(r.steps > 0);
        // Every working parameter is bf16-representable...
        for &w in t.working_params() {
            assert_eq!(w, Bf16::from_f32(w).to_f32(), "working param not bf16");
        }
        // ...while the master keeps full-precision residue the working
        // copy cannot express (Adam steps are far below bf16 ulp).
        let differs = t
            .params()
            .iter()
            .zip(t.working_params())
            .filter(|(m, w)| m != w)
            .count();
        assert!(
            differs > 0,
            "master == working everywhere; split-Adam is not splitting"
        );
    }
}
