//! Parameter sweeps over the paper's Sec. 4.3 grids: measure the native
//! kernels on this host, compute host efficiency, and project onto the
//! paper's machines at equal efficiency (the substitution contract of
//! DESIGN.md §4).

use crate::conv1d::test_util::rnd;
use crate::conv1d::{Backend, ConvParams, ConvPlan, Partition, PlanOptions, PostOps};
use crate::machine::{project, Measurement, Precision, Strategy};
use crate::machine::spec::MachineSpec;

use super::runner::{time_fn, Timing};

/// Which pass to sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Forward,
    BackwardData,
    BackwardWeight,
}

/// One measured + projected sweep point.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub p: ConvParams,
    pub pass: Pass,
    pub backend: Backend,
    pub precision: Precision,
    pub timing: Timing,
    /// Achieved GFLOP/s on this host.
    pub host_gflops: f64,
    /// Efficiency on this host (vs calibrated peak).
    pub host_eff: f64,
    /// Modelled efficiency on the paper machine (CLX for f32 figures,
    /// CPX for bf16), from the roofline model at paper thread counts.
    pub modeled_eff: f64,
    /// Modelled seconds on the paper machine.
    pub modeled_secs: f64,
}

/// Sweep configuration.
pub struct SweepConfig {
    /// Batch size for measured runs (paper uses 56; scaled here).
    pub batch: usize,
    /// Measured repetitions (median reported).
    pub reps: usize,
    /// Cap on measured Q (larger grid points are still *modeled*).
    pub max_measured_q: usize,
    /// Host peak GFLOP/s (from `machine::calibrate_host`).
    pub host_gflops_peak: f64,
    /// Threads for the measured runs.
    pub threads: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            batch: 2,
            reps: 3,
            max_measured_q: 60_000,
            host_gflops_peak: 10.0,
            threads: 1,
        }
    }
}

fn strategy_of(b: Backend) -> Strategy {
    match b {
        Backend::Brgemm => Strategy::Brgemm,
        Backend::Im2col => Strategy::Im2col,
        Backend::Direct => Strategy::Direct,
    }
}

/// Measure one grid point. `(c, k, q, s, d)` are the paper's sweep axes.
#[allow(clippy::too_many_arguments)]
pub fn run_point(
    cfg: &SweepConfig,
    c: usize,
    k: usize,
    q: usize,
    s: usize,
    d: usize,
    pass: Pass,
    backend: Backend,
    precision: Precision,
    paper_machine: &MachineSpec,
) -> SweepRow {
    let q_meas = q.min(cfg.max_measured_q);
    let p = ConvParams::new(cfg.batch, c, k, q_meas + (s - 1) * d, s, d)
        .expect("invalid sweep point");
    let x = rnd(p.n * p.c * p.w, 0xC0 + q as u64);
    let wt = rnd(p.k * p.c * p.s, 0xF1 + s as u64);

    // Build the plan once — the paper's setup phase (JIT + relayout) —
    // then time the steady-state executor only, the way a training loop
    // experiences the kernel. bf16 is only meaningful on the BRGEMM
    // backend; the library baseline always measures f32, as in the paper.
    let plan_precision = if backend == Backend::Brgemm {
        precision
    } else {
        Precision::F32
    };
    let mut plan = ConvPlan::build(
        p,
        wt,
        PlanOptions::new()
            .backend(backend)
            .precision(plan_precision)
            .threads(cfg.threads),
    )
    .expect("sweep plan construction");
    let timing = match pass {
        Pass::Forward => {
            let mut out = vec![0.0f32; p.n * p.k * p.q()];
            time_fn(1, cfg.reps, || {
                plan.execute_forward_into(&x, &mut out);
                std::hint::black_box(&out);
            })
        }
        Pass::BackwardData => {
            let gout = rnd(p.n * p.k * p.q(), 0xAB);
            let mut gin = vec![0.0f32; p.n * p.c * p.w];
            time_fn(1, cfg.reps, || {
                plan.execute_backward_data_into(&gout, &mut gin);
                std::hint::black_box(&gin);
            })
        }
        Pass::BackwardWeight => {
            let gout = rnd(p.n * p.k * p.q(), 0xCD);
            let mut gw = vec![0.0f32; p.k * p.c * p.s];
            time_fn(1, cfg.reps, || {
                plan.execute_backward_weight_into(&gout, &x, &mut gw);
                std::hint::black_box(&gw);
            })
        }
    };

    let meas = Measurement {
        flops: p.flops(),
        secs: timing.median_secs,
        threads: cfg.threads,
    };
    let host = MachineSpec::host(cfg.host_gflops_peak);
    let host_eff = meas.efficiency_on(&host, Precision::F32);
    // Model at the *full* requested Q (q, not q_meas) and paper threads.
    let p_full = ConvParams::new(56, c, k, q + (s - 1) * d, s, d).unwrap();
    let proj = project(
        &p_full,
        strategy_of(backend),
        paper_machine,
        precision,
        paper_machine.cores - 1,
    );
    SweepRow {
        p,
        pass,
        backend,
        precision,
        timing,
        host_gflops: meas.flops_per_sec() / 1e9,
        host_eff,
        modeled_eff: proj.efficiency,
        modeled_secs: proj.secs,
    }
}

/// Measure one forward grid point with the kernel chosen by the
/// process-wide autotuner ([`crate::conv1d::autotuner`]) and a fused
/// post-op epilogue. Returns the steady-state timing plus the chosen
/// kernel's registry name — the sweep/bench binaries report both.
pub fn run_point_tuned(
    cfg: &SweepConfig,
    c: usize,
    k: usize,
    q: usize,
    s: usize,
    d: usize,
    post: PostOps,
) -> (Timing, &'static str) {
    let q_meas = q.min(cfg.max_measured_q);
    let p = ConvParams::new(cfg.batch, c, k, q_meas + (s - 1) * d, s, d)
        .expect("invalid sweep point");
    let x = rnd(p.n * p.c * p.w, 0xC0 + q as u64);
    let wt = rnd(p.k * p.c * p.s, 0xF1 + s as u64);
    let mut plan = ConvPlan::build(
        p,
        wt,
        PlanOptions::new()
            .tuned()
            .threads(cfg.threads)
            .partition(Partition::default())
            .post_ops(post),
    )
    .expect("tuned plan construction");
    if post.bias {
        plan.set_bias(&rnd(k, 0xB1A5));
    }
    let res = if post.residual {
        Some(rnd(p.n * p.k * p.q(), 0xE51D))
    } else {
        None
    };
    let mut out = vec![0.0f32; p.n * p.k * p.q()];
    let timing = time_fn(1, cfg.reps, || {
        plan.execute_forward_post_into(&x, res.as_deref(), &mut out);
        std::hint::black_box(&out);
    });
    (timing, plan.kernel_name())
}

/// Run a full grid (e.g. `experiment::fig4_grid()`) under both the BRGEMM
/// and the baseline backends.
pub fn run_grid(
    cfg: &SweepConfig,
    grid: &[(usize, usize, usize, usize, usize)],
    pass: Pass,
    precision: Precision,
    paper_machine: &MachineSpec,
) -> Vec<(SweepRow, SweepRow)> {
    grid.iter()
        .map(|&(c, k, q, s, d)| {
            let ours = run_point(cfg, c, k, q, s, d, pass, Backend::Brgemm, precision, paper_machine);
            let base = run_point(cfg, c, k, q, s, d, pass, Backend::Im2col, Precision::F32, paper_machine);
            (ours, base)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_produces_sane_numbers() {
        let cfg = SweepConfig {
            batch: 1,
            reps: 2,
            max_measured_q: 2_000,
            host_gflops_peak: 10.0,
            threads: 1,
        };
        let clx = MachineSpec::cascade_lake();
        let row = run_point(&cfg, 15, 15, 1_000, 9, 8, Pass::Forward, Backend::Brgemm, Precision::F32, &clx);
        assert!(row.timing.median_secs > 0.0);
        assert!(row.host_gflops > 0.0);
        assert!(row.modeled_eff > 0.0 && row.modeled_eff <= 1.0);
    }

    #[test]
    fn brgemm_beats_baseline_on_paper_region() {
        // Measured, on this host: eq. 4's claim at a moderate size.
        let cfg = SweepConfig {
            batch: 1,
            reps: 2,
            max_measured_q: 4_000,
            host_gflops_peak: 10.0,
            threads: 1,
        };
        let clx = MachineSpec::cascade_lake();
        let ours = run_point(&cfg, 15, 15, 4_000, 51, 8, Pass::Forward, Backend::Brgemm, Precision::F32, &clx);
        let base = run_point(&cfg, 15, 15, 4_000, 51, 8, Pass::Forward, Backend::Im2col, Precision::F32, &clx);
        // min-of-reps and a small slack: unit tests run in debug builds on
        // a shared core, so guard against scheduler noise — the release
        // benches assert the strict ordering.
        assert!(
            ours.timing.min_secs < base.timing.min_secs * 1.15,
            "BRGEMM {} vs im2col {}",
            ours.timing.min_secs,
            base.timing.min_secs
        );
    }
}
