//! Benchmark harness: timing runner ([`runner`]), paper-grid sweeps
//! ([`sweep`]) and report emitters ([`tables`]). Each bench binary in
//! `rust/benches/` and the `dilconv sweep`/`bench` subcommands build on
//! these to regenerate the paper's tables and figures (DESIGN.md §8).
//!
//! Two environment hooks govern every bench binary:
//!
//! * `BENCH_SMOKE=1` — **fast mode**: shrink shapes and repetition
//!   counts to whatever finishes in seconds, and *never* hard-fail on
//!   performance. This is what CI's `bench-smoke` job runs on shared
//!   runners, where absolute timings are meaningless but the benches
//!   must still execute end-to-end and emit their `BENCH_*.json` rows.
//! * `BENCH_STRICT=1` — turn the printed perf expectations (speedup
//!   floors, overlap wins) into assertions. Only meaningful on a quiet
//!   dedicated host; ignored whenever `BENCH_SMOKE` is set.

pub mod runner;
pub mod sweep;
pub mod tables;

pub use runner::{time_auto, time_fn, Timing};
pub use sweep::{run_grid, run_point, run_point_tuned, Pass, SweepConfig, SweepRow};

/// True when `BENCH_SMOKE` is set: benches run tiny shapes with minimal
/// reps and skip every perf assertion (CI smoke mode).
pub fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// True when perf expectations should hard-fail: `BENCH_STRICT` is set
/// and smoke mode is not (a shared smoke runner must never fail on
/// timing noise, whatever else is exported in its environment).
pub fn strict() -> bool {
    std::env::var_os("BENCH_STRICT").is_some() && !smoke()
}
