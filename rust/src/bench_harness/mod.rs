//! Benchmark harness: timing runner ([`runner`]), paper-grid sweeps
//! ([`sweep`]) and report emitters ([`tables`]). Each bench binary in
//! `rust/benches/` and the `dilconv sweep`/`bench` subcommands build on
//! these to regenerate the paper's tables and figures (DESIGN.md §7).

pub mod runner;
pub mod sweep;
pub mod tables;

pub use runner::{time_auto, time_fn, Timing};
pub use sweep::{run_grid, run_point, run_point_tuned, Pass, SweepConfig, SweepRow};
