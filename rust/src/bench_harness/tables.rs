//! Report emitters: markdown tables (paper-style rows) and CSV files for
//! plotting, used by the CLI and the bench binaries.

use std::fmt::Write as _;
use std::path::Path;

/// Render a markdown table.
pub fn markdown(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", headers.join(" | "));
    let _ = writeln!(
        out,
        "|{}|",
        headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
    for row in rows {
        let _ = writeln!(out, "| {} |", row.join(" | "));
    }
    out
}

/// Write rows as CSV (naive quoting — our values never contain commas).
pub fn write_csv(path: impl AsRef<Path>, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    let mut s = String::new();
    let _ = writeln!(s, "{}", headers.join(","));
    for row in rows {
        let _ = writeln!(s, "{}", row.join(","));
    }
    std::fs::write(path, s)
}

/// Format an efficiency fraction as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format seconds with 4 significant digits.
pub fn secs(x: f64) -> String {
    if x >= 1.0 {
        format!("{x:.3}s")
    } else {
        format!("{:.3}ms", x * 1e3)
    }
}

/// Format a speedup like the paper ("6.86x").
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Canonical table/CSV cell for a backend: the registry name via
/// `Display`, which round-trips with `Backend::from_str` — replaces the
/// ad-hoc `{:?}` labels the reports used to emit.
pub fn backend_cell(b: crate::conv1d::Backend) -> String {
    b.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let md = markdown(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "| a | b |");
        assert_eq!(lines[1], "|---|---|");
        assert_eq!(lines[3], "| 3 | 4 |");
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.8012), "80.1%");
        assert_eq!(secs(2.5), "2.500s");
        assert_eq!(secs(0.0021), "2.100ms");
        assert_eq!(speedup(6.864), "6.86x");
    }

    #[test]
    fn backend_cells_round_trip() {
        use crate::conv1d::Backend;
        for b in Backend::ALL {
            let cell = backend_cell(b);
            assert_eq!(cell.parse::<Backend>().unwrap(), b, "{cell}");
        }
        assert_eq!(backend_cell(Backend::Im2col), "im2col");
    }

    #[test]
    fn csv_roundtrip() {
        let p = std::env::temp_dir().join("dilconv_csv_test.csv");
        write_csv(&p, &["x", "y"], &[vec!["1".into(), "2".into()]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "x,y\n1,2\n");
    }
}
