//! Benchmark runner: warmup + repeated timing with median/min reporting —
//! the in-tree replacement for criterion (offline build), tuned for
//! kernel-scale (µs–s) measurements.

use crate::metrics::timing::Stats;
use std::time::Instant;

/// Result of timing one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_secs: f64,
    pub min_secs: f64,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub reps: usize,
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn time_fn(warmup: usize, reps: usize, mut f: impl FnMut()) -> Timing {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    let mut stats = Stats::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        stats.push(dt);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if reps % 2 == 1 {
        samples[reps / 2]
    } else {
        0.5 * (samples[reps / 2 - 1] + samples[reps / 2])
    };
    Timing {
        median_secs: median,
        min_secs: samples[0],
        mean_secs: stats.mean(),
        stddev_secs: stats.stddev(),
        reps,
    }
}

/// Auto-scaled timing: picks a repetition count so the total measured time
/// stays near `budget_secs` (at least `min_reps`).
pub fn time_auto(budget_secs: f64, min_reps: usize, mut f: impl FnMut()) -> Timing {
    // One calibration run (also serves as warmup).
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((budget_secs / once) as usize).clamp(min_reps, 10_000);
    time_fn(0, reps, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_of_constant_work() {
        let t = time_fn(1, 5, || {
            std::hint::black_box((0..20_000).map(|i| i as f64).sum::<f64>());
        });
        assert_eq!(t.reps, 5);
        assert!(t.median_secs > 0.0);
        assert!(t.min_secs <= t.median_secs);
        assert!(t.median_secs <= t.mean_secs + t.stddev_secs * 3.0 + 1e-3);
    }

    #[test]
    fn auto_scaling_bounds_reps() {
        let t = time_auto(0.01, 3, || {
            std::hint::black_box((0..1_000).sum::<usize>());
        });
        assert!(t.reps >= 3);
    }
}
