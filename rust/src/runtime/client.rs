//! PJRT execution wrapper: loads HLO-text artifacts on the CPU client and
//! caches compiled executables.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not
//! serialized protos — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! (See /opt/xla-example/README.md.)

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU session with an executable cache.
pub struct Session {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Session {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Session> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session {
            client,
            cache: HashMap::new(),
        })
    }

    /// Platform description for logs.
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Load + compile an HLO text file, caching by `key`.
    pub fn load(&mut self, key: &str, path: impl AsRef<Path>) -> Result<()> {
        if self.cache.contains_key(key) {
            return Ok(());
        }
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        self.cache.insert(key.to_string(), exe);
        Ok(())
    }

    /// Execute a cached executable. All our artifacts are lowered with
    /// `return_tuple=True`, so the single output literal is decomposed
    /// into the tuple elements.
    pub fn run(&self, key: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .cache
            .get(key)
            .with_context(|| format!("executable '{key}' not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing '{key}'"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("decomposing result tuple")
    }

    /// Number of cached executables.
    pub fn loaded(&self) -> usize {
        self.cache.len()
    }
}

/// Host tensor (f32, row-major) ↔ `xla::Literal` conversion helpers.
pub mod literal {
    use anyhow::{Context, Result};

    /// Build an f32 literal of the given shape from a host slice.
    pub fn f32_tensor(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let elems: usize = shape.iter().product::<usize>().max(1);
        anyhow::ensure!(
            elems == data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        if shape.is_empty() {
            return Ok(xla::Literal::scalar(data[0]));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .context("reshaping literal")
    }

    /// Scalar f32 literal.
    pub fn f32_scalar(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// Extract an f32 vector from a literal.
    pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().context("literal to f32 vec")
    }

    /// Extract an f32 scalar.
    pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
        lit.get_first_element::<f32>()
            .context("literal first element")
    }
}
