//! Stub train/eval step runners, compiled when the `xla` feature is off.
//! Mirrors the public surface of `runtime::step` (the flat-parameter ABI
//! types) so the CLI, tests and examples compile; all execution entry
//! points fail at run time.

use anyhow::{bail, Result};

use super::artifacts::{Artifact, Registry};
use super::client::Session;

const UNAVAILABLE: &str =
    "PJRT unavailable: dilconv1d was built without the `xla` feature (see rust/DESIGN.md §10)";

/// Losses returned by one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepLosses {
    pub total: f32,
    pub mse: f32,
    pub bce: f32,
}

/// Mutable training state for a model variant (flat f32 ABI).
pub struct TrainState {
    pub variant: String,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
    /// Expected batch/width of the lowered train_step artifact.
    pub batch: usize,
    pub width: usize,
}

impl TrainState {
    /// Always fails in the stub build.
    pub fn init(_reg: &Registry, _variant: &str) -> Result<TrainState> {
        bail!(UNAVAILABLE)
    }

    /// Artifact key of this variant's train step.
    pub fn train_key(&self) -> String {
        format!("train_step_{}", self.variant)
    }

    /// Artifact key of this variant's eval step.
    pub fn eval_key(&self) -> String {
        format!("eval_step_{}", self.variant)
    }

    /// Always fails in the stub build.
    pub fn step(
        &mut self,
        _sess: &Session,
        _x: &[f32],
        _clean: &[f32],
        _peaks: &[f32],
    ) -> Result<StepLosses> {
        bail!(UNAVAILABLE)
    }

    /// Always fails in the stub build.
    pub fn eval(&self, _sess: &Session, _x: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!(UNAVAILABLE)
    }
}

/// Always fails in the stub build.
pub fn run_conv_fwd(
    _sess: &mut Session,
    _art: &Artifact,
    _x: &[f32],
    _w_skc: &[f32],
) -> Result<Vec<f32>> {
    bail!(UNAVAILABLE)
}
