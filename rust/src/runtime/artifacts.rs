//! Artifact registry: discovers and describes the HLO-text artifacts that
//! `make artifacts` (python/compile/aot.py) emitted, via
//! `artifacts/meta.json`.
//!
//! Every artifact entry records its kind (conv_fwd, train_step, …), input
//! and output tensor shapes (the Rust↔HLO ABI) and, for model artifacts,
//! the flat-parameter packing spec used by the coordinator/checkpointing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor shape+dtype as recorded in meta.json.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("bad shape in tensor spec"))?,
        })
    }
}

/// One named parameter tensor inside the flat packing.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model metadata attached to train/eval/grad artifacts.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub channels: usize,
    pub n_blocks: usize,
    pub filter_size: usize,
    pub dilation: usize,
    pub n_conv_layers: usize,
    pub param_count: usize,
    pub param_spec: Vec<ParamEntry>,
}

/// A single artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub kind: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub flops: Option<u64>,
    pub model: Option<ModelMeta>,
    pub batch: Option<usize>,
    pub width: Option<usize>,
}

/// The registry of all artifacts in a directory.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Registry {
    /// Load `dir/meta.json` and build the registry.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?}; run `make artifacts` first"))?;
        let doc = Json::parse(&text).context("parsing meta.json")?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| anyhow!("meta.json root must be an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let kind = entry
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            let path = if kind == "params" {
                dir.join(
                    entry
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("params entry without file"))?,
                )
            } else {
                dir.join(format!("{name}.hlo.txt"))
            };
            let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                match entry.get(key) {
                    Some(Json::Arr(v)) => v.iter().map(TensorSpec::from_json).collect(),
                    _ => Ok(Vec::new()),
                }
            };
            let model = entry.get("model").map(|m| -> Result<ModelMeta> {
                let usz = |k: &str| {
                    m.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("model meta missing {k}"))
                };
                let mut param_spec = Vec::new();
                if let Some(list) = m.get("param_spec").and_then(Json::as_arr) {
                    for pe in list {
                        param_spec.push(ParamEntry {
                            name: pe
                                .get("name")
                                .and_then(Json::as_str)
                                .unwrap_or("")
                                .to_string(),
                            shape: pe
                                .get("shape")
                                .and_then(Json::as_usize_vec)
                                .unwrap_or_default(),
                            offset: pe.get("offset").and_then(Json::as_usize).unwrap_or(0),
                            size: pe.get("size").and_then(Json::as_usize).unwrap_or(0),
                        });
                    }
                }
                Ok(ModelMeta {
                    channels: usz("channels")?,
                    n_blocks: usz("n_blocks")?,
                    filter_size: usz("filter_size")?,
                    dilation: usz("dilation")?,
                    n_conv_layers: usz("n_conv_layers")?,
                    param_count: usz("param_count")?,
                    param_spec,
                })
            });
            let model = match model {
                Some(m) => Some(m?),
                None => None,
            };
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    kind,
                    path,
                    inputs: tensors("inputs")?,
                    outputs: tensors("outputs")?,
                    flops: entry.get("flops").and_then(Json::as_f64).map(|f| f as u64),
                    model,
                    batch: entry.get("batch").and_then(Json::as_usize),
                    width: entry.get("width").and_then(Json::as_usize),
                },
            );
        }
        Ok(Registry { dir, artifacts })
    }

    /// Lookup by name, with a helpful error.
    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not found (have: {})",
                self.artifacts.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Read the packed initial parameters for a model variant.
    pub fn load_params(&self, variant: &str) -> Result<Vec<f32>> {
        let art = self.get(&format!("params_{variant}"))?;
        let bytes = std::fs::read(&art.path)
            .with_context(|| format!("reading {:?}", art.path))?;
        if bytes.len() % 4 != 0 {
            bail!("params file not a multiple of 4 bytes");
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("meta.json"), body).unwrap();
    }

    #[test]
    fn parses_registry() {
        let dir = std::env::temp_dir().join("dilconv_test_registry");
        write_meta(
            &dir,
            r#"{
              "conv_fwd_x": {
                "kind": "conv_fwd",
                "inputs": [{"dtype": "f32", "shape": [2, 3, 100]}],
                "outputs": [{"dtype": "f32", "shape": [2, 4, 90]}],
                "flops": 12345
              },
              "train_step_t": {
                "kind": "train_step",
                "batch": 2, "width": 512,
                "model": {"channels": 15, "n_blocks": 2, "filter_size": 51,
                          "dilation": 8, "n_conv_layers": 7, "param_count": 100,
                          "param_spec": [{"name": "conv0.w", "shape": [15,1,51],
                                          "offset": 0, "size": 765}]}
              }
            }"#,
        );
        let reg = Registry::load(&dir).unwrap();
        let a = reg.get("conv_fwd_x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3, 100]);
        assert_eq!(a.inputs[0].elements(), 600);
        assert_eq!(a.flops, Some(12345));
        let t = reg.get("train_step_t").unwrap();
        let m = t.model.as_ref().unwrap();
        assert_eq!(m.n_conv_layers, 7);
        assert_eq!(m.param_spec[0].size, 765);
        assert!(reg.get("nope").is_err());
    }

    #[test]
    fn loads_params_blob() {
        let dir = std::env::temp_dir().join("dilconv_test_params");
        write_meta(
            &dir,
            r#"{"params_v": {"kind": "params", "file": "params_v.f32.bin"}}"#,
        );
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("params_v.f32.bin"), bytes).unwrap();
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.load_params("v").unwrap(), vals);
    }
}
