//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — Python never runs
//! on the training path.
//!
//! * [`artifacts`] — registry over `artifacts/meta.json`
//! * [`client`]    — PJRT CPU session + executable cache + literal helpers
//! * [`step`]      — train/eval step runners (the flat-parameter ABI)
//!
//! The PJRT-backed `client`/`step` modules require the `xla` feature
//! (and the `xla` bindings crate). The default offline build substitutes
//! API-identical stubs that fail at run time, so everything downstream —
//! CLI, tests, examples — compiles either way (DESIGN.md §10).

pub mod artifacts;

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod step;

#[cfg(not(feature = "xla"))]
#[path = "client_stub.rs"]
pub mod client;
#[cfg(not(feature = "xla"))]
#[path = "step_stub.rs"]
pub mod step;

pub use artifacts::{Artifact, ModelMeta, Registry, TensorSpec};
pub use client::Session;
pub use step::{StepLosses, TrainState};
