//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — Python never runs
//! on the training path.
//!
//! * [`artifacts`] — registry over `artifacts/meta.json`
//! * [`client`]    — PJRT CPU session + executable cache + literal helpers
//! * [`step`]      — train/eval step runners (the flat-parameter ABI)

pub mod artifacts;
pub mod client;
pub mod step;

pub use artifacts::{Artifact, ModelMeta, Registry, TensorSpec};
pub use client::Session;
pub use step::{StepLosses, TrainState};
