//! Stub PJRT session, compiled when the `xla` feature is off (the default
//! offline build). Keeps the `runtime::client` API surface identical to
//! the real client so callers compile unchanged; every operation that
//! would touch PJRT fails at run time with a clear message. The runtime
//! integration tests and `dilconv artifacts-check` already skip when
//! `artifacts/` is absent, so the default build degrades gracefully.

use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT unavailable: dilconv1d was built without the `xla` feature (see rust/DESIGN.md §10)";

/// A PJRT CPU session placeholder.
pub struct Session {
    _private: (),
}

impl Session {
    /// Always fails in the stub build.
    pub fn cpu() -> Result<Session> {
        bail!(UNAVAILABLE)
    }

    /// Platform description for logs.
    pub fn platform(&self) -> String {
        "unavailable (built without the xla feature)".to_string()
    }

    /// Always fails in the stub build.
    pub fn load(&mut self, _key: &str, _path: impl AsRef<Path>) -> Result<()> {
        bail!(UNAVAILABLE)
    }

    /// Number of cached executables (always zero in the stub).
    pub fn loaded(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_fails_with_a_clear_message() {
        let e = Session::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("xla"), "{e}");
    }
}
