//! Train/eval step runners over loaded HLO artifacts — the Rust side of
//! the L2 ABI defined in python/compile/aot.py.
//!
//! A [`TrainState`] holds the flat parameter vector plus Adam moments; one
//! `step()` call feeds `(params, m, v, step, x, clean, peaks)` to the
//! `train_step_<variant>` executable and swaps in the returned state.
//! Python is never involved: the HLO was lowered once at build time.

use anyhow::{ensure, Context, Result};

use super::artifacts::{Artifact, Registry};
use super::client::{literal, Session};

/// Losses returned by one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepLosses {
    pub total: f32,
    pub mse: f32,
    pub bce: f32,
}

/// Mutable training state for a model variant (flat f32 ABI).
pub struct TrainState {
    pub variant: String,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
    /// Expected batch/width of the lowered train_step artifact.
    pub batch: usize,
    pub width: usize,
}

impl TrainState {
    /// Initialise from the registry's packed initial parameters.
    pub fn init(reg: &Registry, variant: &str) -> Result<TrainState> {
        let art = reg.get(&format!("train_step_{variant}"))?;
        let model = art
            .model
            .as_ref()
            .context("train_step artifact missing model meta")?;
        let params = reg.load_params(variant)?;
        ensure!(
            params.len() == model.param_count,
            "params blob length {} != param_count {}",
            params.len(),
            model.param_count
        );
        Ok(TrainState {
            variant: variant.to_string(),
            m: vec![0.0; params.len()],
            v: vec![0.0; params.len()],
            params,
            step: 0.0,
            batch: art.batch.context("train_step missing batch")?,
            width: art.width.context("train_step missing width")?,
        })
    }

    /// Artifact key of this variant's train step.
    pub fn train_key(&self) -> String {
        format!("train_step_{}", self.variant)
    }

    /// Artifact key of this variant's eval step.
    pub fn eval_key(&self) -> String {
        format!("eval_step_{}", self.variant)
    }

    /// Run one Adam step on `(x, clean, peaks)` batches of shape
    /// `(batch, 1, width)` flattened row-major.
    pub fn step(
        &mut self,
        sess: &Session,
        x: &[f32],
        clean: &[f32],
        peaks: &[f32],
    ) -> Result<StepLosses> {
        let shape = [self.batch, 1, self.width];
        let inputs = vec![
            literal::f32_tensor(&self.params, &[self.params.len()])?,
            literal::f32_tensor(&self.m, &[self.m.len()])?,
            literal::f32_tensor(&self.v, &[self.v.len()])?,
            literal::f32_scalar(self.step),
            literal::f32_tensor(x, &shape)?,
            literal::f32_tensor(clean, &shape)?,
            literal::f32_tensor(peaks, &shape)?,
        ];
        let out = sess.run(&self.train_key(), &inputs)?;
        ensure!(out.len() == 6, "train_step returned {} outputs", out.len());
        self.params = literal::to_f32_vec(&out[0])?;
        self.m = literal::to_f32_vec(&out[1])?;
        self.v = literal::to_f32_vec(&out[2])?;
        self.step += 1.0;
        Ok(StepLosses {
            total: literal::to_f32_scalar(&out[3])?,
            mse: literal::to_f32_scalar(&out[4])?,
            bce: literal::to_f32_scalar(&out[5])?,
        })
    }

    /// Run the eval step: returns `(denoised, peak_probabilities)`, each
    /// `(batch, 1, width)` flattened.
    pub fn eval(&self, sess: &Session, x: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let shape = [self.batch, 1, self.width];
        let inputs = vec![
            literal::f32_tensor(&self.params, &[self.params.len()])?,
            literal::f32_tensor(x, &shape)?,
        ];
        let out = sess.run(&self.eval_key(), &inputs)?;
        ensure!(out.len() == 2, "eval_step returned {} outputs", out.len());
        Ok((literal::to_f32_vec(&out[0])?, literal::to_f32_vec(&out[1])?))
    }
}

/// Load + run a conv_fwd artifact (runtime integration of the L1 kernel).
pub fn run_conv_fwd(
    sess: &mut Session,
    art: &Artifact,
    x: &[f32],
    w_skc: &[f32],
) -> Result<Vec<f32>> {
    sess.load(&art.name, &art.path)?;
    let inputs = vec![
        literal::f32_tensor(x, &art.inputs[0].shape)?,
        literal::f32_tensor(w_skc, &art.inputs[1].shape)?,
    ];
    let out = sess.run(&art.name, &inputs)?;
    literal::to_f32_vec(&out[0])
}
