//! Trainable layer wrappers with activation caching for the fixed-topology
//! backward pass (the native engine's "autograd tape" is the network
//! structure itself; see resnet.rs).

use crate::conv1d::layout::{pad_width_into, unpad_width};
use crate::conv1d::{Backend, Conv1dLayer, ConvParams, Partition, PostOps};
use crate::machine::Precision;

use super::tensor::Tensor;

/// A same-padded conv layer with bias, caching its padded input for the
/// backward pass. Width-preserving: `(N, C, W) -> (N, K, W)`.
///
/// Steady-state training reuses everything across steps: the layer's
/// cached [`crate::conv1d::ConvPlan`] (derived layouts, offset tables,
/// kernel scratch) and this wrapper's persistent padded-input buffers —
/// the per-step re-pad allocation of the pre-plan design is gone.
/// Training and eval forwards pad into *separate* buffers, so an eval
/// pass between `forward(train=true)` and `backward()` cannot corrupt
/// the cached training input.
pub struct ConvSame {
    pub conv: Conv1dLayer,
    /// Persistent padded-input buffer for `forward(train=true)`; holds
    /// the cached input the backward pass consumes.
    xp_train: Vec<f32>,
    /// Persistent padded-input buffer for eval forwards.
    xp_eval: Vec<f32>,
    /// Saved post-op output of the last `forward_fused(train=true)` —
    /// the fused backward reconstructs activation gradients from it
    /// (no mask tensors exist on the fused path).
    y_train: Vec<f32>,
    /// `(n, wp, fused)` of the input cached by the last training
    /// forward; the flag records *which* forward path produced it, so a
    /// backward can never consume the wrong cache (the fused backward
    /// needs `y_train`, which only `forward_fused` writes).
    cached: Option<(usize, usize, bool)>,
}

/// Gradients of one conv layer.
pub struct ConvGrads {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl ConvSame {
    pub fn new(c: usize, k: usize, s: usize, d: usize, weights: Vec<f32>) -> Self {
        ConvSame {
            conv: Conv1dLayer::new(c, k, s, d, weights),
            xp_train: Vec::new(),
            xp_eval: Vec::new(),
            y_train: Vec::new(),
            cached: None,
        }
    }

    pub fn set_backend(&mut self, backend: Backend, threads: usize) {
        self.conv.backend = backend;
        self.conv.threads = threads;
    }

    /// Select the work partitioning the conv kernels split across
    /// threads: batch-dimension (paper) or the 2D width-block grid
    /// (saturates a socket even at N = 1).
    pub fn set_partition(&mut self, partition: Partition) {
        self.conv.partition = partition;
    }

    /// Select the forward precision (bf16 takes effect on the BRGEMM
    /// backend; others fall back to f32). Under BF16 *training* the
    /// trainer pairs this with split Adam: the weights loaded into this
    /// layer are the bf16 rounding of an FP32 master copy
    /// ([`crate::model::MasterWeights`]), while every gradient this
    /// layer produces stays f32 (DESIGN.md §6).
    pub fn set_precision(&mut self, precision: Precision) {
        self.conv.precision = precision;
    }

    /// Attach the post-op epilogue the fused paths apply.
    pub fn set_post_ops(&mut self, ops: PostOps) {
        self.conv.post_ops = ops;
    }

    /// Set the static activation quantization scale the i8 tier uses for
    /// this layer's input (calibrated absmax / 127; ignored under
    /// f32/bf16). Cheap: refreshes the plan's dequant row without a plan
    /// rebuild.
    pub fn set_input_scale(&mut self, scale: f32) {
        self.conv.input_scale = scale;
    }

    /// Route kernel selection through the process-wide autotuner.
    pub fn set_autotune(&mut self, on: bool) {
        self.conv.autotune = on;
    }

    /// Forward-only mode for serving: plans drop their backward scratch
    /// ([`crate::conv1d::ConvPlan::with_inference`]) and any backward
    /// call panics. Eval forwards (`train = false`) already skip the
    /// activation/padded-input caching, so an inference layer's steady
    /// state is one fused pass plus the persistent pad buffer.
    pub fn set_inference(&mut self, on: bool) {
        self.conv.inference = on;
    }

    /// Eagerly build the conv plan (and pre-size the eval pad buffer)
    /// for an unpadded `(n, w)` problem — the serving plan cache warms
    /// each bucket this way at startup, so the first request never pays
    /// plan construction.
    pub fn warm(&mut self, n: usize, w: usize) -> Result<(), crate::conv1d::PlanError> {
        let (l, r) = ConvParams::same_pad(self.conv.s, self.conv.d);
        let need = n * self.conv.c * (w + l + r);
        if self.xp_eval.len() != need {
            self.xp_eval.resize(need, 0.0);
        }
        self.conv.try_warm(n, w + l + r)
    }

    /// Workspace bytes held by this layer's cached plan (0 before the
    /// first forward/warm).
    pub fn plan_workspace_bytes(&self) -> usize {
        self.conv.plan_workspace_bytes()
    }

    /// Shared same-padding prologue of both forward paths: pad `x` into
    /// the persistent train/eval buffer and return the padded width.
    fn pad_into_buffer(&mut self, x: &Tensor, train: bool) -> usize {
        let (l, r) = ConvParams::same_pad(self.conv.s, self.conv.d);
        let wp = x.w + l + r;
        let need = x.n * x.c * wp;
        let buf = if train {
            &mut self.xp_train
        } else {
            &mut self.xp_eval
        };
        if buf.len() != need {
            buf.resize(need, 0.0);
        }
        pad_width_into(&x.data, x.n, x.c, x.w, l, r, buf);
        wp
    }

    /// Fused forward: same-padding + the layer's post-op epilogue
    /// (bias/activation/residual) applied inside the kernel's output
    /// block loop — one pass over the output instead of the legacy
    /// conv + bias-sweep (+ caller relu-sweep). `residual` must be a
    /// `(N, K, W)` tensor iff the spec has `residual` set. With `train`,
    /// caches the padded input *and* the post-op output for
    /// [`Self::backward_fused`].
    pub fn forward_fused(&mut self, x: &Tensor, residual: Option<&Tensor>, train: bool) -> Tensor {
        let wp = self.pad_into_buffer(x, train);
        let buf = if train { &self.xp_train } else { &self.xp_eval };
        let out = self
            .conv
            .try_forward_post(buf, residual.map(|t| t.data.as_slice()), x.n, wp)
            .unwrap_or_else(|e| panic!("{e}"));
        if train {
            self.y_train.clear();
            self.y_train.extend_from_slice(&out);
            self.cached = Some((x.n, wp, true));
        }
        Tensor::from_vec(out, x.n, self.conv.k, x.w)
    }

    /// Fused backward: consumes the cached padded input and saved output.
    /// One prologue sweep folds the activation gradient, the bias
    /// gradient and (when `need_gres`) the residual gradient together,
    /// then the kernel backward passes run on the masked gradient —
    /// no separate mask/bias sweeps. Returns
    /// `(grad_input?, grad_residual?, grads)`.
    pub fn backward_fused(
        &mut self,
        gout: &Tensor,
        need_gin: bool,
        need_gres: bool,
    ) -> (Option<Tensor>, Option<Tensor>, ConvGrads) {
        let (n, wp, fused) = self
            .cached
            .take()
            .expect("backward_fused() without a cached forward_fused(train=true)");
        assert!(
            fused,
            "backward_fused() after a legacy forward(train=true); the fused backward \
             needs the saved output only forward_fused caches"
        );
        assert_eq!(gout.n, n);
        assert_eq!(gout.c, self.conv.k);
        let (l, r) = ConvParams::same_pad(self.conv.s, self.conv.d);
        debug_assert_eq!(gout.w + l + r, wp);
        let xp = &self.xp_train[..n * self.conv.c * wp];
        let y = &self.y_train[..n * self.conv.k * gout.w];
        let fg = self
            .conv
            .try_backward_fused(&gout.data, y, xp, n, wp, need_gin, need_gres)
            .unwrap_or_else(|e| panic!("{e}"));
        let gin = fg.gin.map(|gxp| {
            let gx = unpad_width(&gxp, n, self.conv.c, wp, l, r);
            Tensor::from_vec(gx, n, self.conv.c, gout.w)
        });
        let gres = fg
            .res
            .map(|gr| Tensor::from_vec(gr, n, self.conv.k, gout.w));
        (gin, gres, ConvGrads { w: fg.w, b: fg.b })
    }

    /// Forward, caching the padded input when `train` is set.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let wp = self.pad_into_buffer(x, train);
        let buf = if train { &self.xp_train } else { &self.xp_eval };
        let mut out = self.conv.forward(buf, x.n, wp);
        // Bias.
        for ib in 0..x.n {
            for ik in 0..self.conv.k {
                let b = self.conv.bias[ik];
                if b != 0.0 {
                    for v in &mut out[(ib * self.conv.k + ik) * x.w..(ib * self.conv.k + ik + 1) * x.w] {
                        *v += b;
                    }
                }
            }
        }
        if train {
            self.cached = Some((x.n, wp, false));
        }
        Tensor::from_vec(out, x.n, self.conv.k, x.w)
    }

    /// Backward: consumes the cached input; returns (grad_input, grads).
    pub fn backward(&mut self, gout: &Tensor) -> (Tensor, ConvGrads) {
        let (n, wp, fused) = self
            .cached
            .take()
            .expect("backward() without a cached forward(train=true)");
        assert!(!fused, "backward() after forward_fused(train=true); use backward_fused");
        assert_eq!(gout.n, n);
        assert_eq!(gout.c, self.conv.k);
        let (l, r) = ConvParams::same_pad(self.conv.s, self.conv.d);
        debug_assert_eq!(gout.w + l + r, wp);
        let xp = &self.xp_train[..n * self.conv.c * wp];
        let gw = self.conv.backward_weight(&gout.data, xp, n, wp);
        let gb = self.conv.backward_bias(&gout.data, n, gout.w);
        let gxp = self.conv.backward_data(&gout.data, n, wp);
        let gx = unpad_width(&gxp, n, self.conv.c, wp, l, r);
        (
            Tensor::from_vec(gx, n, self.conv.c, gout.w),
            ConvGrads { w: gw, b: gb },
        )
    }

    /// Backward-weight only (used by the stem, whose input needs no grad).
    pub fn backward_weights_only(&mut self, gout: &Tensor) -> ConvGrads {
        let (n, wp, fused) = self
            .cached
            .take()
            .expect("backward() without a cached forward(train=true)");
        assert!(!fused, "backward_weights_only() after forward_fused(train=true)");
        let xp = &self.xp_train[..n * self.conv.c * wp];
        let gw = self.conv.backward_weight(&gout.data, xp, n, wp);
        let gb = self.conv.backward_bias(&gout.data, n, gout.w);
        ConvGrads { w: gw, b: gb }
    }

    pub fn k(&self) -> usize {
        self.conv.k
    }

    pub fn weight_len(&self) -> usize {
        self.conv.weights().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::test_util::rnd;

    #[test]
    fn forward_preserves_width() {
        let mut l = ConvSame::new(3, 5, 7, 2, rnd(5 * 3 * 7, 1));
        let x = Tensor::from_vec(rnd(2 * 3 * 90, 2), 2, 3, 90);
        let y = l.forward(&x, false);
        assert_eq!(y.shape(), (2, 5, 90));
    }

    #[test]
    fn backward_gradcheck_weights() {
        // Finite-difference check of dLoss/dw for Loss = <g, forward(x)>.
        let (c, k, s, d, n, w) = (2, 2, 3, 2, 1, 24);
        let w0 = rnd(k * c * s, 3);
        let x = Tensor::from_vec(rnd(n * c * w, 4), n, c, w);
        let g = Tensor::from_vec(rnd(n * k * w, 5), n, k, w);

        let mut layer = ConvSame::new(c, k, s, d, w0.clone());
        layer.forward(&x, true);
        let (_, grads) = layer.backward(&g);

        let eps = 1e-2f32;
        for wi in 0..w0.len() {
            let mut wp = w0.clone();
            wp[wi] += eps;
            let yp = ConvSame::new(c, k, s, d, wp).forward(&x, false);
            let mut wm = w0.clone();
            wm[wi] -= eps;
            let ym = ConvSame::new(c, k, s, d, wm).forward(&x, false);
            let fd: f32 = yp
                .data
                .iter()
                .zip(&ym.data)
                .zip(&g.data)
                .map(|((a, b), gg)| (a - b) / (2.0 * eps) * gg)
                .sum();
            assert!(
                (fd - grads.w[wi]).abs() < 3e-2 * (1.0 + grads.w[wi].abs()),
                "w[{wi}] fd {fd} vs {}",
                grads.w[wi]
            );
        }
    }

    #[test]
    fn backward_gradcheck_input() {
        let (c, k, s, d, n, w) = (2, 3, 3, 1, 1, 16);
        let w0 = rnd(k * c * s, 6);
        let x0 = rnd(n * c * w, 7);
        let g = Tensor::from_vec(rnd(n * k * w, 8), n, k, w);
        let mut layer = ConvSame::new(c, k, s, d, w0.clone());
        layer.forward(&Tensor::from_vec(x0.clone(), n, c, w), true);
        let (gx, _) = layer.backward(&g);
        let eps = 1e-2f32;
        for xi in (0..x0.len()).step_by(5) {
            let mut xp = x0.clone();
            xp[xi] += eps;
            let yp = layer.forward(&Tensor::from_vec(xp, n, c, w), false);
            let mut xm = x0.clone();
            xm[xi] -= eps;
            let ym = layer.forward(&Tensor::from_vec(xm, n, c, w), false);
            let fd: f32 = yp
                .data
                .iter()
                .zip(&ym.data)
                .zip(&g.data)
                .map(|((a, b), gg)| (a - b) / (2.0 * eps) * gg)
                .sum();
            assert!(
                (fd - gx.data[xi]).abs() < 3e-2 * (1.0 + gx.data[xi].abs()),
                "x[{xi}] fd {fd} vs {}",
                gx.data[xi]
            );
        }
    }

    #[test]
    fn fused_forward_backward_match_legacy_three_pass() {
        // The fused bias+relu path must reproduce the legacy pipeline —
        // conv, bias sweep, relu sweep; masked backward — bit for bit.
        let (c, k, s, d, n, w) = (3, 4, 5, 2, 2, 60);
        let wts = rnd(k * c * s, 20);
        let bias = vec![0.1, -0.2, 0.3, 0.4];
        let mut fused = ConvSame::new(c, k, s, d, wts.clone());
        fused.conv.bias = bias.clone();
        fused.set_post_ops(PostOps::bias_relu());
        let mut legacy = ConvSame::new(c, k, s, d, wts);
        legacy.conv.bias = bias;
        let x = Tensor::from_vec(rnd(n * c * w, 21), n, c, w);
        let y = fused.forward_fused(&x, None, true);
        let mut want = legacy.forward(&x, true);
        let mask = want.relu_inplace();
        assert_eq!(y.data, want.data, "fused forward != conv+bias+relu");

        let g = Tensor::from_vec(rnd(n * k * w, 22), n, k, w);
        let (gin, gres, grads) = fused.backward_fused(&g, true, false);
        assert!(gres.is_none());
        let mut gm = g.clone();
        Tensor::mask_gradient(&mut gm.data, &mask);
        let (gin_want, grads_want) = legacy.backward(&gm);
        assert_eq!(gin.unwrap().data, gin_want.data, "fused gin");
        assert_eq!(grads.w, grads_want.w, "fused gw");
        for (a, b) in grads.b.iter().zip(&grads_want.b) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "fused gb {a} vs {b}");
        }
    }

    #[test]
    fn fused_residual_matches_manual_skip_add() {
        let (c, k, s, d, n, w) = (2, 3, 5, 2, 1, 40);
        let wts = rnd(k * c * s, 30);
        let bias = vec![0.05, -0.1, 0.15];
        let mut fused = ConvSame::new(c, k, s, d, wts.clone());
        fused.conv.bias = bias.clone();
        fused.set_post_ops(PostOps::bias_relu_residual());
        let mut legacy = ConvSame::new(c, k, s, d, wts);
        legacy.conv.bias = bias;
        let x = Tensor::from_vec(rnd(n * c * w, 31), n, c, w);
        let res = Tensor::from_vec(rnd(n * k * w, 32), n, k, w);
        // Legacy: conv+bias, then the separate skip add, then relu.
        let mut want = legacy.forward(&x, true);
        want.add_assign(&res);
        let mask = want.relu_inplace();
        let y = fused.forward_fused(&x, Some(&res), true);
        assert_eq!(y.data, want.data, "fused residual forward");
        // Fused backward: the residual gradient is the masked gradient.
        let g = Tensor::from_vec(rnd(n * k * w, 33), n, k, w);
        let (gin, gres, _) = fused.backward_fused(&g, true, true);
        let mut gm = g.clone();
        Tensor::mask_gradient(&mut gm.data, &mask);
        assert_eq!(gres.unwrap().data, gm.data, "residual gradient == masked gout");
        let (gin_want, _) = legacy.backward(&gm);
        assert_eq!(gin.unwrap().data, gin_want.data, "fused residual gin");
    }

    #[test]
    fn bias_gradient_is_gout_sum() {
        let (c, k, s, d, n, w) = (1, 2, 3, 1, 2, 10);
        let mut layer = ConvSame::new(c, k, s, d, rnd(k * c * s, 9));
        let x = Tensor::from_vec(rnd(n * c * w, 10), n, c, w);
        layer.forward(&x, true);
        let g = Tensor::from_vec(vec![1.0; n * k * w], n, k, w);
        let (_, grads) = layer.backward(&g);
        for &gb in &grads.b {
            assert!((gb - (n * w) as f32).abs() < 1e-4);
        }
    }
}
