//! The AtacWorks-like network in the native engine (paper Sec. 4.2):
//! 25 same-padded dilated conv layers — stem, 11 residual blocks of two
//! convs each, and two heads (denoising regression + peak classification)
//! — with a hand-written, fixed-topology backward pass whose conv
//! gradients run through the paper's Algorithm 3/4 kernels.
//!
//! Every layer routes through the **fused post-op pipeline**
//! (DESIGN.md §5b): the stem and the first block conv fuse `bias + act`,
//! the second block conv fuses `bias + act + residual` (the skip
//! connection is added inside the conv's output-block loop), and the
//! heads fuse `bias`. Forward is one pass per layer instead of the
//! pre-fusion conv + bias sweep + relu sweep; backward reconstructs
//! activation gradients from the saved outputs, so no mask tensors exist.
//!
//! The architecture and parameter packing order mirror
//! python/compile/model.py exactly (conv0.w, conv0.b, conv1.w, …), so
//! checkpoints and gradients interoperate between the native and PJRT
//! paths.

use crate::conv1d::{Activation, Backend, PostOps};
use crate::util::rng::Rng;

use super::layers::{ConvGrads, ConvSame};
use super::loss::{bce_with_grad, mse_with_grad};
use super::netplan::NetPlan;
use super::tensor::Tensor;

/// Network hyperparameters (mirror of python ModelConfig).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetConfig {
    /// Channels (15 for FP32 runs, 16 for BF16 runs; paper Sec. 4.4).
    pub channels: usize,
    /// Residual blocks (11 → 25 conv layers total).
    pub n_blocks: usize,
    /// Filter width (paper: 51).
    pub filter_size: usize,
    /// Dilation (paper: 8).
    pub dilation: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            channels: 15,
            n_blocks: 11,
            filter_size: 51,
            dilation: 8,
        }
    }
}

impl NetConfig {
    /// Scaled-down config for tests.
    pub fn tiny() -> Self {
        NetConfig {
            channels: 4,
            n_blocks: 1,
            filter_size: 9,
            dilation: 2,
        }
    }

    pub fn n_conv_layers(&self) -> usize {
        1 + 2 * self.n_blocks + 2
    }

    /// One-sided receptive-field reach of a head output column, in input
    /// columns: how far left (or right) of output column `j` the input
    /// can influence it. Each same-padded conv reaches
    /// `ceil((S-1)/2) · d` columns per side, and the deepest path from
    /// the input to either head crosses `2·n_blocks + 2` convs (stem,
    /// two per block, one head — the heads are parallel, not stacked).
    /// This is the halo a streaming window must overlap so its interior
    /// columns are bit-identical to whole-sequence evaluation
    /// ([`crate::serve::StreamingSession`]; DESIGN.md §7b).
    ///
    /// Tiny config (S=9, d=2, 1 block): 4 layers × 8 = 32. Paper config
    /// (S=51, d=8, 11 blocks): 24 layers × 200 = 4800.
    pub fn receptive_field_reach(&self) -> usize {
        let per_layer = (self.filter_size - 1).div_ceil(2) * self.dilation;
        (2 * self.n_blocks + 2) * per_layer
    }

    /// `(K, C, S)` of every conv layer in packing order.
    pub fn layer_shapes(&self) -> Vec<(usize, usize, usize)> {
        let (ch, s) = (self.channels, self.filter_size);
        let mut v = vec![(ch, 1, s)];
        for _ in 0..self.n_blocks {
            v.push((ch, ch, s));
            v.push((ch, ch, s));
        }
        v.push((1, ch, s));
        v.push((1, ch, s));
        v
    }

    /// Total flat parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.layer_shapes()
            .iter()
            .map(|&(k, c, s)| k * c * s + k)
            .sum()
    }

    /// Flat parameter count (weights + bias) of every layer, packing
    /// order — the per-layer spans of the flat vector, and the unit of
    /// gradient bucketing ([`crate::dist::BucketPlan`]).
    pub fn layer_param_counts(&self) -> Vec<usize> {
        self.layer_shapes()
            .iter()
            .map(|&(k, c, s)| k * c * s + k)
            .collect()
    }

    /// Layer ids in the order their gradients complete during the
    /// backward pass: the two heads first, then each residual block in
    /// reverse (second conv, then first), the stem last. This is the
    /// order [`AtacWorksNet::forward_backward_streaming`] invokes its
    /// sink, and the order gradient buckets fill.
    pub fn backward_completion_order(&self) -> Vec<usize> {
        let nb = self.n_blocks;
        let mut order = Vec::with_capacity(self.n_conv_layers());
        order.push(1 + 2 * nb);
        order.push(2 + 2 * nb);
        for b in (0..nb).rev() {
            order.push(2 + 2 * b);
            order.push(1 + 2 * b);
        }
        order.push(0);
        order
    }
}

/// Losses of one forward/backward pass.
#[derive(Debug, Clone, Copy)]
pub struct Losses {
    pub total: f64,
    pub mse: f64,
    pub bce: f64,
}

/// The network: conv layers in packing order.
pub struct AtacWorksNet {
    pub cfg: NetConfig,
    pub convs: Vec<ConvSame>,
    /// Net-level execution plan (liveness arena + conv→conv fusion,
    /// DESIGN.md §7c). Built lazily on the first eval-mode pass and
    /// rebuilt whenever the input shape or a layer knob stops matching.
    netplan: Option<NetPlan>,
    /// Routing switch for the eval paths (`forward(x, false)`, `infer`,
    /// `infer_masked`): `true` (default) executes through the
    /// [`NetPlan`]; `false` keeps the per-layer pipeline — the
    /// conformance reference the plan is bit-identical to.
    netplan_enabled: bool,
    /// Conv→conv fusion inside the netplan. Off, the plan still runs the
    /// per-layer kernels out of the shared arena.
    fuse: bool,
}

impl AtacWorksNet {
    /// All-zero parameters — the constructor for callers that overwrite
    /// the weights immediately (e.g. [`Self::unpack_params`] from a
    /// checkpoint or a parameter server): no He-init RNG fill is paid
    /// for values that never get read.
    pub fn zeros(cfg: NetConfig) -> Self {
        let convs = cfg
            .layer_shapes()
            .into_iter()
            .map(|(k, c, s)| ConvSame::new(c, k, s, cfg.dilation, vec![0.0f32; k * c * s]))
            .collect();
        let mut net = AtacWorksNet {
            cfg,
            convs,
            netplan: None,
            netplan_enabled: true,
            fuse: true,
        };
        net.set_activation(Activation::Relu);
        net
    }

    /// He-initialised network (same scheme as the L2 model).
    pub fn init(cfg: NetConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut net = Self::zeros(cfg);
        for c in &mut net.convs {
            let (k, ch, s) = (c.k(), c.conv.c, c.conv.s);
            let std = (2.0 / (ch * s) as f64).sqrt() as f32;
            let mut w = vec![0.0f32; k * ch * s];
            rng.fill_normal_f32(&mut w, std);
            c.conv.set_weights(w);
        }
        net
    }

    /// Select the kernel backend + thread count for every layer.
    pub fn set_backend(&mut self, backend: Backend, threads: usize) {
        for c in &mut self.convs {
            c.set_backend(backend, threads);
        }
    }

    /// Select the work partitioning for every layer (batch-dimension or
    /// the 2D width-block grid).
    pub fn set_partition(&mut self, partition: crate::conv1d::Partition) {
        for c in &mut self.convs {
            c.set_partition(partition);
        }
    }

    /// Select the forward precision for every layer (bf16 takes effect on
    /// the BRGEMM backend; gradients stay f32).
    pub fn set_precision(&mut self, precision: crate::machine::Precision) {
        for c in &mut self.convs {
            c.set_precision(precision);
        }
    }

    /// Set the per-layer static activation quantization scales the i8
    /// tier consumes, in packing order — one per conv layer, as returned
    /// by [`Self::calibrate_input_scales`]. Ignored under f32/bf16.
    pub fn set_input_scales(&mut self, scales: &[f32]) {
        assert_eq!(
            scales.len(),
            self.convs.len(),
            "one input scale per conv layer"
        );
        for (c, &s) in self.convs.iter_mut().zip(scales) {
            c.set_input_scale(s);
        }
    }

    /// Activation calibration for the i8 tier: run one f32 eval forward
    /// over a warm-up batch and record, per conv layer in packing order,
    /// the quantization scale (`absmax / 127`) of the tensor that layer
    /// consumes. Call this on an **f32-precision** net (the serving
    /// engine calibrates on a temporary f32 net before switching the
    /// production net to i8); the scales are static afterwards, so every
    /// later request — any batch size, bucket, or streamed window — sees
    /// identical quantization and the bit-identity matrices hold.
    pub fn calibrate_input_scales(&mut self, x: &Tensor) -> Vec<f32> {
        use crate::conv1d::quant::{absmax, scale_from_absmax};
        assert_eq!(x.c, 1, "input must be single-channel");
        let nb = self.cfg.n_blocks;
        let mut scales = vec![1.0f32; self.cfg.n_conv_layers()];
        scales[0] = scale_from_absmax(absmax(&x.data));
        let mut h = self.convs[0].forward_fused(x, None, false);
        for b in 0..nb {
            let c1 = 1 + 2 * b;
            let c2 = c1 + 1;
            scales[c1] = scale_from_absmax(absmax(&h.data));
            let r = self.convs[c1].forward_fused(&h, None, false);
            scales[c2] = scale_from_absmax(absmax(&r.data));
            h = self.convs[c2].forward_fused(&r, Some(&h), false);
        }
        // Both heads consume the same body output.
        let sh = scale_from_absmax(absmax(&h.data));
        scales[1 + 2 * nb] = sh;
        scales[2 + 2 * nb] = sh;
        scales
    }

    /// Route every layer's kernel selection through the process-wide
    /// autotuner.
    pub fn set_autotune(&mut self, on: bool) {
        for c in &mut self.convs {
            c.set_autotune(on);
        }
    }

    /// Forward-only serving mode: every layer's plans are built via
    /// [`crate::conv1d::ConvPlan::with_inference`] — no backward scratch
    /// is allocated (for the 25-layer network that is most of a plan's
    /// footprint) and training entry points panic. Pair with
    /// [`Self::infer`], which also skips the activation saving a
    /// `forward(train = true)` would do.
    pub fn set_inference(&mut self, on: bool) {
        for c in &mut self.convs {
            c.set_inference(on);
        }
    }

    /// Route the eval paths (`forward(x, false)`, [`Self::infer`],
    /// [`Self::infer_masked`]) through the net-level [`NetPlan`]
    /// (default) or through the per-layer reference pipeline. Training
    /// (`forward(x, true)`) always uses the per-layer path — backward
    /// needs each layer's cached activations.
    pub fn set_netplan(&mut self, on: bool) {
        self.netplan_enabled = on;
        if !on {
            self.netplan = None;
        }
    }

    /// Enable/disable conv→conv fusion inside the net-level plan. With
    /// fusion off the plan still single-buffers intermediates through
    /// the liveness arena. Takes effect on the next eval pass (the plan
    /// key tracks this knob).
    pub fn set_fuse(&mut self, on: bool) {
        self.fuse = on;
    }

    /// Whether eval passes currently execute through the net-level plan.
    pub fn netplan_enabled(&self) -> bool {
        self.netplan_enabled
    }

    /// The currently built net-level plan, if any eval pass (or
    /// [`Self::warm`]) has run.
    pub fn netplan(&self) -> Option<&NetPlan> {
        self.netplan.as_ref()
    }

    /// Build (or rebuild) the net plan so it matches the convs' knobs
    /// and the `(n, w)` shape. Rebuilds are detected via the plan key —
    /// see [`NetPlan::matches`].
    fn ensure_netplan(&mut self, n: usize, w: usize) {
        let stale = match &self.netplan {
            Some(p) => !p.matches(&self.convs, n, w, self.fuse),
            None => true,
        };
        if stale {
            self.netplan = Some(NetPlan::build(self.cfg, &self.convs, n, w, self.fuse));
        }
    }

    /// Eagerly build every plan needed to serve a batch of `n` unpadded
    /// width-`w` tracks — the serving plan cache warms each width bucket
    /// this way at startup (DESIGN.md §7). With the netplan routing
    /// active this builds the net-level plan plus the per-layer plans it
    /// still dispatches (all of them unfused; only the heads when
    /// fusion folds the body chains into BRGEMM strips).
    pub fn warm(&mut self, n: usize, w: usize) -> Result<(), crate::conv1d::PlanError> {
        if self.netplan_enabled {
            self.ensure_netplan(n, w);
            let idxs = self
                .netplan
                .as_ref()
                .expect("ensure_netplan just built the plan")
                .per_layer_indices();
            for l in idxs {
                self.convs[l].warm(n, w)?;
            }
        } else {
            for c in &mut self.convs {
                c.warm(n, w)?;
            }
        }
        Ok(())
    }

    /// Total workspace bytes across every layer's cached plan — what one
    /// serving plan-cache entry holds resident.
    pub fn plan_workspace_bytes(&self) -> usize {
        self.convs.iter().map(|c| c.plan_workspace_bytes()).sum()
    }

    /// Forward-only inference: `x (N, 1, W)` → `(denoised, peak logits)`,
    /// both `(N, 1, W)`. No activation or padded-input caching happens
    /// (the eval pad buffers are reused), so this is the serving
    /// steady-state path: one fused pass per layer and zero retained
    /// per-request state.
    pub fn infer(&mut self, x: &Tensor) -> (Tensor, Tensor) {
        let (denoised, logits, _) = self.forward(x, false);
        (denoised, logits)
    }

    /// Zero-allocation inference core: run the net-level plan into
    /// caller-owned `(N, 1, W)` output tensors, with optional per-row
    /// width masking (`widths: None` ≡ every row at full width). This is
    /// the serving steady-state entry point — the engine's bucket
    /// entries own `den`/`logits` and the plan's arena, so a warmed call
    /// touches the heap not at all. Panics if `netplan` routing was
    /// switched off via [`Self::set_netplan`].
    pub fn infer_masked_into(
        &mut self,
        x: &Tensor,
        widths: Option<&[usize]>,
        den: &mut Tensor,
        logits: &mut Tensor,
    ) -> Result<(), crate::conv1d::PlanError> {
        assert!(
            self.netplan_enabled,
            "infer_masked_into requires netplan routing (set_netplan(true))"
        );
        if let Some(ws) = widths {
            assert_eq!(ws.len(), x.n, "one width per batch row");
            assert!(
                ws.iter().all(|&wv| wv <= x.w),
                "row widths cannot exceed the padded tensor width"
            );
        }
        self.ensure_netplan(x.n, x.w);
        let plan = self
            .netplan
            .as_mut()
            .expect("ensure_netplan just built the plan");
        plan.execute(&self.convs, x, widths, den, logits)
    }

    /// Width-masked forward-only inference for zero-padded rows: row `r`
    /// of `x` carries a real track in columns `0..widths[r]` and zeros
    /// beyond. After every body layer the pad tail of each row is
    /// re-zeroed, so the tail always holds exactly the zeros that
    /// same-padding at the row's native width would supply — without
    /// masking, layer 1 writes non-zero values (bias, activation,
    /// boundary taps) into the tail and deeper layers fold them back
    /// into real columns within the receptive field. With it, each
    /// row's first `widths[r]` output columns are **bit-identical** to
    /// running that row alone at width `widths[r]` (per-element FMA
    /// order is width-independent), so a serving bucket is purely an
    /// execution shape, never part of the model (DESIGN.md §7).
    pub fn infer_masked(&mut self, x: &Tensor, widths: &[usize]) -> (Tensor, Tensor) {
        assert_eq!(widths.len(), x.n, "one width per batch row");
        assert!(
            widths.iter().all(|&wv| wv <= x.w),
            "row widths cannot exceed the padded tensor width"
        );
        if self.netplan_enabled {
            let mut den = Tensor::zeros(x.n, 1, x.w);
            let mut logits = Tensor::zeros(x.n, 1, x.w);
            self.infer_masked_into(x, Some(widths), &mut den, &mut logits)
                .unwrap_or_else(|e| panic!("net plan rejected the shape: {e}"));
            return (den, logits);
        }
        fn mask_tail(t: &mut Tensor, widths: &[usize]) {
            for (row, &wv) in widths.iter().enumerate() {
                for ch in 0..t.c {
                    let base = (row * t.c + ch) * t.w;
                    t.data[base + wv..base + t.w].fill(0.0);
                }
            }
        }
        let nb = self.cfg.n_blocks;
        let mut h = self.convs[0].forward_fused(x, None, false);
        mask_tail(&mut h, widths);
        for b in 0..nb {
            let c1 = 1 + 2 * b;
            let c2 = c1 + 1;
            let mut r = self.convs[c1].forward_fused(&h, None, false);
            mask_tail(&mut r, widths);
            h = self.convs[c2].forward_fused(&r, Some(&h), false);
            mask_tail(&mut h, widths);
        }
        // Head outputs need no mask: callers only read the real columns.
        let denoised = self.convs[1 + 2 * nb].forward_fused(&h, None, false);
        let logits = self.convs[2 + 2 * nb].forward_fused(&h, None, false);
        (denoised, logits)
    }

    /// Select the body activation and (re)attach each layer's fused
    /// post-op spec by role: stem and first block conv fuse
    /// `bias + act`, second block conv fuses `bias + act + residual`,
    /// heads fuse `bias` only.
    pub fn set_activation(&mut self, act: Activation) {
        let nb = self.cfg.n_blocks;
        let body = PostOps::bias().with_activation(act);
        self.convs[0].set_post_ops(body);
        for b in 0..nb {
            self.convs[1 + 2 * b].set_post_ops(body);
            self.convs[2 + 2 * b].set_post_ops(body.with_residual(true));
        }
        self.convs[1 + 2 * nb].set_post_ops(PostOps::bias());
        self.convs[2 + 2 * nb].set_post_ops(PostOps::bias());
    }

    /// Forward pass. `x: (N, 1, W)`; returns `(denoised, logits)`, both
    /// `(N, 1, W)`. With `train` set, each layer caches what its fused
    /// backward needs (padded input + post-op output) — the returned
    /// [`ForwardCache`] is an empty compatibility token.
    ///
    /// Every layer is one fused pass: the relu lives inside the conv's
    /// output-block loop, and the skip connection is added there too
    /// (`relu(conv(r) + bias + h)`), so no separate add/relu sweeps run.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> (Tensor, Tensor, ForwardCache) {
        assert_eq!(x.c, 1, "input must be single-channel");
        if !train && self.netplan_enabled {
            let mut den = Tensor::zeros(x.n, 1, x.w);
            let mut logits = Tensor::zeros(x.n, 1, x.w);
            self.infer_masked_into(x, None, &mut den, &mut logits)
                .unwrap_or_else(|e| panic!("net plan rejected the shape: {e}"));
            return (den, logits, ForwardCache::default());
        }
        let nb = self.cfg.n_blocks;

        let mut h = self.convs[0].forward_fused(x, None, train); // stem: bias+act
        for b in 0..nb {
            let c1 = 1 + 2 * b;
            let c2 = c1 + 1;
            let r = self.convs[c1].forward_fused(&h, None, train);
            h = self.convs[c2].forward_fused(&r, Some(&h), train);
        }

        let denoised = self.convs[1 + 2 * nb].forward_fused(&h, None, train);
        let logits = self.convs[2 + 2 * nb].forward_fused(&h, None, train);
        (denoised, logits, ForwardCache::default())
    }

    /// Full training step math: forward + losses + backward.
    /// Returns per-layer gradients (packing order) and the losses.
    pub fn forward_backward(
        &mut self,
        x: &Tensor,
        clean: &Tensor,
        peaks: &Tensor,
    ) -> (Vec<ConvGrads>, Losses) {
        let n_layers = self.convs.len();
        let mut slots: Vec<Option<ConvGrads>> = (0..n_layers).map(|_| None).collect();
        let losses = self.forward_backward_streaming(x, clean, peaks, |layer, grads| {
            slots[layer] = Some(grads);
        });
        let out = slots
            .into_iter()
            .map(|s| s.expect("backward visited every layer"))
            .collect();
        (out, losses)
    }

    /// Full training step math with a **streaming gradient sink**: the
    /// sink is invoked with `(layer_id, grads)` the moment each layer's
    /// backward completes, in [`NetConfig::backward_completion_order`] —
    /// heads, blocks reversed, stem. This is the hook the bucketed,
    /// overlapped all-reduce hangs off: a gradient bucket can start its
    /// collective while earlier layers are still differentiating.
    pub fn forward_backward_streaming(
        &mut self,
        x: &Tensor,
        clean: &Tensor,
        peaks: &Tensor,
        mut sink: impl FnMut(usize, ConvGrads),
    ) -> Losses {
        let nb = self.cfg.n_blocks;
        let (denoised, logits, _) = self.forward(x, true);
        let (l_mse, g_mse) = mse_with_grad(&denoised.data, &clean.data);
        let (l_bce, g_bce) = bce_with_grad(&logits.data, &peaks.data);
        let losses = Losses {
            total: l_mse + l_bce,
            mse: l_mse,
            bce: l_bce,
        };

        let g_den = Tensor::from_vec(g_mse, denoised.n, denoised.c, denoised.w);
        let g_log = Tensor::from_vec(g_bce, logits.n, logits.c, logits.w);

        // Heads (bias fused; identity activation).
        let (gh_reg, _, grads_reg) = self.convs[1 + 2 * nb].backward_fused(&g_den, true, false);
        sink(1 + 2 * nb, grads_reg);
        let (gh_cls, _, grads_cls) = self.convs[2 + 2 * nb].backward_fused(&g_log, true, false);
        sink(2 + 2 * nb, grads_cls);
        let mut gh = gh_reg.expect("head backward produces an input gradient");
        gh.add_assign(&gh_cls.expect("head backward produces an input gradient"));

        // Blocks, reversed. The second conv's fused backward hands back
        // both the branch gradient (through the conv) and the residual
        // gradient (the skip path) from one prologue sweep.
        for b in (0..nb).rev() {
            let c1 = 1 + 2 * b;
            let c2 = c1 + 1;
            let (gu, gskip, g2) = self.convs[c2].backward_fused(&gh, true, true);
            sink(c2, g2);
            let (gbranch, _, g1) = self.convs[c1].backward_fused(
                &gu.expect("block conv produces an input gradient"),
                true,
                false,
            );
            sink(c1, g1);
            gh = gbranch.expect("block conv produces an input gradient");
            gh.add_assign(&gskip.expect("residual gradient requested")); // skip + branch
        }

        // Stem (input gradient not needed).
        let (_, _, grads_stem) = self.convs[0].backward_fused(&gh, false, false);
        sink(0, grads_stem);
        losses
    }

    /// Flatten parameters in the shared packing order (convN.w, convN.b).
    pub fn pack_params(&self) -> Vec<f32> {
        let mut flat = Vec::with_capacity(self.cfg.param_count());
        for c in &self.convs {
            flat.extend_from_slice(c.conv.weights());
            flat.extend_from_slice(&c.conv.bias);
        }
        flat
    }

    /// Load parameters from the flat packing.
    pub fn unpack_params(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.cfg.param_count(), "param length mismatch");
        let mut off = 0;
        for c in &mut self.convs {
            let wl = c.weight_len();
            c.conv.set_weights(flat[off..off + wl].to_vec());
            off += wl;
            let k = c.k();
            c.conv.bias.copy_from_slice(&flat[off..off + k]);
            off += k;
        }
    }

    /// Flatten per-layer gradients in the same packing order.
    pub fn pack_grads(&self, grads: &[ConvGrads]) -> Vec<f32> {
        let mut flat = Vec::with_capacity(self.cfg.param_count());
        for g in grads {
            flat.extend_from_slice(&g.w);
            flat.extend_from_slice(&g.b);
        }
        flat
    }
}

/// Compatibility token returned by [`AtacWorksNet::forward`]. Since the
/// fused post-op pipeline, each [`ConvSame`] caches its own backward
/// state (padded input + saved output) — no mask tensors exist anymore.
#[derive(Default)]
pub struct ForwardCache {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn batch(cfg: &NetConfig, n: usize, w: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let _ = cfg;
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * w];
        let mut clean = vec![0.0f32; n * w];
        let mut peaks = vec![0.0f32; n * w];
        for i in 0..n * w {
            clean[i] = rng.poisson(1.5) as f32;
            x[i] = rng.poisson(0.3) as f32;
            peaks[i] = f32::from(rng.chance(0.1));
        }
        (
            Tensor::from_vec(x, n, 1, w),
            Tensor::from_vec(clean, n, 1, w),
            Tensor::from_vec(peaks, n, 1, w),
        )
    }

    #[test]
    fn shapes_and_param_count() {
        let cfg = NetConfig::default();
        assert_eq!(cfg.n_conv_layers(), 25); // paper: 25 conv layers
        let tiny = NetConfig::tiny();
        let net = AtacWorksNet::init(tiny, 1);
        assert_eq!(net.pack_params().len(), tiny.param_count());
    }

    #[test]
    fn receptive_field_reach_counts_the_deepest_head_path() {
        // Tiny: 4 convs deep (stem + 2 + head), each reaching
        // ((9-1)/2)*2 = 8 columns per side.
        assert_eq!(NetConfig::tiny().receptive_field_reach(), 32);
        // Paper: 24 convs deep, ((51-1)/2)*8 = 200 per layer.
        assert_eq!(NetConfig::default().receptive_field_reach(), 4800);
        // Even filter widths round the per-layer reach up.
        let even = NetConfig {
            channels: 2,
            n_blocks: 1,
            filter_size: 4,
            dilation: 3,
        };
        assert_eq!(even.receptive_field_reach(), 4 * 2 * 3);
    }

    #[test]
    fn forward_output_shapes() {
        let cfg = NetConfig::tiny();
        let mut net = AtacWorksNet::init(cfg, 2);
        let (x, _, _) = batch(&cfg, 2, 100, 3);
        let (den, log, _) = net.forward(&x, false);
        assert_eq!(den.shape(), (2, 1, 100));
        assert_eq!(log.shape(), (2, 1, 100));
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let cfg = NetConfig::tiny();
        let net = AtacWorksNet::init(cfg, 4);
        let flat = net.pack_params();
        let mut net2 = AtacWorksNet::init(cfg, 99);
        net2.unpack_params(&flat);
        assert_eq!(net2.pack_params(), flat);
    }

    #[test]
    fn gradients_match_finite_difference() {
        // End-to-end gradcheck through the residual topology.
        let cfg = NetConfig {
            channels: 2,
            n_blocks: 1,
            filter_size: 3,
            dilation: 1,
        };
        let mut net = AtacWorksNet::init(cfg, 5);
        let (x, clean, peaks) = batch(&cfg, 1, 12, 6);
        let (grads, _) = net.forward_backward(&x, &clean, &peaks);
        let gflat = net.pack_grads(&grads);
        let p0 = net.pack_params();
        let eps = 2e-3f32;
        let mut loss_at = |params: &[f32]| -> f64 {
            net.unpack_params(params);
            let (den, log, _) = net.forward(&x, false);
            let (lm, _) = super::mse_with_grad(&den.data, &clean.data);
            let (lb, _) = super::bce_with_grad(&log.data, &peaks.data);
            lm + lb
        };
        // Spot-check a spread of parameters. ReLU kinks make individual
        // finite differences unreliable at exactly-zero activations (the
        // Poisson input has many zeros), so require a large majority to
        // match rather than every single one.
        let mut checked = 0;
        let mut ok = 0;
        for pi in (0..p0.len()).step_by(p0.len() / 17 + 1) {
            let mut pp = p0.clone();
            pp[pi] += eps;
            let g1 = loss_at(&pp);
            pp[pi] = p0[pi] - eps;
            let g2 = loss_at(&pp);
            let fd = (g1 - g2) / (2.0 * eps as f64);
            checked += 1;
            if (fd - gflat[pi] as f64).abs() < 2e-2 * (1.0 + gflat[pi].abs() as f64) {
                ok += 1;
            }
        }
        assert!(
            ok * 10 >= checked * 8,
            "finite-difference gradcheck: only {ok}/{checked} parameters matched"
        );
        net.unpack_params(&p0);
    }

    #[test]
    fn streaming_backward_matches_collected_and_orders_layers() {
        let cfg = NetConfig::tiny();
        let mut net = AtacWorksNet::init(cfg, 11);
        let (x, clean, peaks) = batch(&cfg, 2, 60, 12);
        let (want_grads, want_losses) = net.forward_backward(&x, &clean, &peaks);
        let mut seen = Vec::new();
        let mut got: Vec<Option<ConvGrads>> = (0..cfg.n_conv_layers()).map(|_| None).collect();
        let losses = net.forward_backward_streaming(&x, &clean, &peaks, |layer, g| {
            seen.push(layer);
            got[layer] = Some(g);
        });
        assert_eq!(seen, cfg.backward_completion_order());
        assert_eq!(losses.total, want_losses.total);
        for (l, (g, w)) in got.iter().zip(&want_grads).enumerate() {
            let g = g.as_ref().expect("layer visited");
            assert_eq!(g.w, w.w, "layer {l} weight grads");
            assert_eq!(g.b, w.b, "layer {l} bias grads");
        }
    }

    #[test]
    fn completion_order_is_a_permutation_and_spans_match() {
        for cfg in [NetConfig::tiny(), NetConfig::default()] {
            let order = cfg.backward_completion_order();
            let n = cfg.n_conv_layers();
            assert_eq!(order.len(), n);
            let mut seen = vec![false; n];
            for &l in &order {
                assert!(!seen[l]);
                seen[l] = true;
            }
            // Heads first, stem last.
            assert_eq!(order[0], n - 2);
            assert_eq!(order[1], n - 1);
            assert_eq!(*order.last().unwrap(), 0);
            assert_eq!(
                cfg.layer_param_counts().iter().sum::<usize>(),
                cfg.param_count()
            );
        }
    }

    #[test]
    fn infer_matches_eval_forward_and_inference_mode_is_bit_identical() {
        let cfg = NetConfig::tiny();
        let mut net = AtacWorksNet::init(cfg, 3);
        // Per-layer reference pipeline (netplan routing off) — the bits
        // the fused/arena plan must reproduce.
        net.set_netplan(false);
        let (x, _, _) = batch(&cfg, 2, 96, 4);
        let (den_want, log_want, _) = net.forward(&x, false);
        // Forward-only mode with warmed plans computes the same bits
        // through the net-level plan (fusion + arena on by default).
        let mut serve = AtacWorksNet::init(cfg, 3);
        serve.set_inference(true);
        serve.warm(2, 96).unwrap();
        let warmed = serve.plan_workspace_bytes();
        assert!(warmed > 0);
        let (den, logits) = serve.infer(&x);
        assert_eq!(den.data, den_want.data);
        assert_eq!(logits.data, log_want.data);
        // Inference plans kept their trimmed workspaces (no rebuild) and
        // are smaller than the training net's.
        assert_eq!(serve.plan_workspace_bytes(), warmed);
        assert!(net.plan_workspace_bytes() > warmed);
    }

    #[test]
    fn masked_inference_is_bit_identical_to_native_width() {
        // A zero-padded row run through infer_masked must reproduce the
        // same row executed alone at its native width, bit for bit —
        // the invariant the serving buckets stand on.
        let cfg = NetConfig::tiny();
        let (w_native, w_padded) = (90usize, 160usize);
        let (x, _, _) = batch(&cfg, 1, w_native, 21);
        let mut native = AtacWorksNet::init(cfg, 13);
        // Per-layer reference: the masked fused plan must match it.
        native.set_netplan(false);
        let (den_want, log_want, _) = native.forward(&x, false);
        let mut padded = vec![0.0f32; w_padded];
        padded[..w_native].copy_from_slice(&x.data);
        let mut serve = AtacWorksNet::init(cfg, 13);
        let (den, logits) =
            serve.infer_masked(&Tensor::from_vec(padded, 1, 1, w_padded), &[w_native]);
        assert_eq!(&den.data[..w_native], &den_want.data[..], "denoised");
        assert_eq!(&logits.data[..w_native], &log_want.data[..], "logits");
        // Unmasked inference does NOT have this property — the pad tail
        // feeds back through deeper layers' receptive fields.
        let mut padded2 = vec![0.0f32; w_padded];
        padded2[..w_native].copy_from_slice(&x.data);
        let (den_unmasked, _) = serve.infer(&Tensor::from_vec(padded2, 1, 1, w_padded));
        assert_ne!(
            &den_unmasked.data[..w_native],
            &den_want.data[..],
            "without masking the bucket width would leak into the output"
        );
    }

    #[test]
    fn i8_calibration_tracks_f32_within_budget() {
        // Calibrate on an f32 net, switch to the i8 tier, and check the
        // quantized forward stays within the multi-layer error budget
        // (per layer |Δ| ≲ C·S·(Ax·s_w/2 + Aw·s_x/2), compounding
        // through the 4-conv tiny topology).
        let cfg = NetConfig::tiny();
        let mut net = AtacWorksNet::init(cfg, 17);
        net.set_netplan(false);
        let (x, _, _) = batch(&cfg, 2, 80, 18);
        let (den_f32, _, _) = net.forward(&x, false);
        let scales = net.calibrate_input_scales(&x);
        assert_eq!(scales.len(), cfg.n_conv_layers());
        assert!(scales.iter().all(|s| s.is_finite() && *s > 0.0));
        net.set_precision(crate::machine::Precision::I8);
        net.set_input_scales(&scales);
        let (den_i8, _, _) = net.forward(&x, false);
        assert_ne!(den_i8.data, den_f32.data, "i8 tier did not engage");
        let err: f32 = den_i8
            .data
            .iter()
            .zip(&den_f32.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let mag: f32 = den_f32.data.iter().map(|v| v * v).sum();
        let rel = err.sqrt() / mag.sqrt().max(1.0);
        assert!(rel < 0.25, "i8 relative L2 error {rel} exceeds budget");
    }

    #[test]
    fn training_reduces_loss() {
        use crate::model::optimizer::Adam;
        let cfg = NetConfig::tiny();
        let mut net = AtacWorksNet::init(cfg, 7);
        let (x, clean, peaks) = batch(&cfg, 2, 80, 8);
        let mut params = net.pack_params();
        let mut opt = Adam::new(params.len(), 5e-3);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            net.unpack_params(&params);
            let (grads, losses) = net.forward_backward(&x, &clean, &peaks);
            let g = net.pack_grads(&grads);
            opt.step(&mut params, &g);
            first.get_or_insert(losses.total);
            last = losses.total;
        }
        assert!(
            last < first.unwrap() * 0.9,
            "loss did not decrease: {} -> {last}",
            first.unwrap()
        );
    }
}
