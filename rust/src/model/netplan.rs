//! Net-level execution plan: cross-layer activation arena + block-wise
//! conv→conv fusion (DESIGN.md §7c, ROADMAP item 4).
//!
//! Per-layer plans ([`crate::conv1d::ConvPlan`]) already keep each conv's
//! *internal* steady state allocation-free, but the net still
//! materialised a full `(N, C, W)` tensor between every pair of its
//! layers — ~25 round-trips to memory per forward pass. A [`NetPlan`]
//! compiles the whole topology once and executes it out of persistent
//! buffers:
//!
//! * **Arena liveness.** Every inter-layer intermediate is assigned a
//!   slot in one arena by a linear-scan liveness analysis
//!   (`assign_slots`): a value's slot is recycled the moment its last
//!   consumer has run. The residual skip keeps `h` alive across both of
//!   a block's convs, so the analysis works on the real dataflow reads
//!   (input *and* residual), not layer adjacency — the same topology
//!   discipline the training path's `backward_completion_order` relies
//!   on. The live-set maximum is 3 slots for the resnet topology
//!   (producer + skip + consumer), independent of depth.
//!
//! * **Block-wise fusion.** For the stem→block and intra-block conv
//!   pairs, a producer's 64-wide output block is consumed by the next
//!   conv's BRGEMM while it is still hot in L2. The fused executor runs
//!   a demand-driven schedule per image: the deepest stage pulls output
//!   blocks left-to-right, and each upstream stage produces exactly the
//!   halo-extended coverage its consumer's next block reads — the same
//!   reach arithmetic `NetConfig::receptive_field_reach` encodes per
//!   layer (`demand = min(W, pos + nb + right_pad)`). The per-layer
//!   fused [`crate::conv1d::PostOps`] epilogue is the intra-fusion
//!   boundary case: it runs per block on the hot strip, exactly as the
//!   per-layer kernels run it per block on the output row.
//!
//! ## Why fusion is bit-identical
//!
//! The fused executor performs, per output element, the *same* FMA
//! reduction the per-layer BRGEMM path performs:
//!
//! * Each stage's block is computed by the same
//!   `brgemm_f32_with`/`brgemm_bf16_with` call with the same
//!   `(m = K, n = nb, k = C, l_br = S)` shape, the same `(S,K,C)` weight
//!   relayout and the same tap offsets `b_offs[s] = pos + s·d`. Only
//!   `ldb`/`ldc` differ (padded strips instead of whole tensors), and
//!   leading dimensions move *stores*, never the accumulation order.
//! * The epilogue routes through [`crate::conv1d::post::apply_segment`]
//!   — the identical per-filter primitive `apply_block` uses in the
//!   per-layer path.
//! * Under bf16, intermediates are stored as the f32 accumulator and
//!   narrowed element-wise (`narrow_row_into`) exactly where the
//!   per-layer path narrows its padded input staging; rounding is
//!   per-element, so narrowing block-by-block gives the same bits as
//!   narrowing the whole row.
//! * Width masking (`infer_masked`'s per-layer tail re-zeroing) happens
//!   on each producer block *before* any consumer reads it — the fusion
//!   boundary — so bucket invariance survives fusion unchanged.
//!
//! `tests/net_plan.rs` locks fused ≡ per-layer (`f32::to_bits`) across
//! {f32, bf16} × {batch, grid} × {masked, unmasked}.

use crate::conv1d::bf16::{narrow_row_into, to_bf16_into, Bf16};
use crate::conv1d::brgemm::{brgemm_bf16_with, brgemm_f32_with};
use crate::conv1d::layout::{kcs_to_skc_into, pad_width_into};
use crate::conv1d::post::apply_segment;
use crate::conv1d::threading::par_batch_chunks_scratch;
use crate::conv1d::{simd, Backend, ConvParams, PlanError, WIDTH_BLOCK};
use crate::machine::Precision;

use super::layers::ConvSame;
use super::resnet::NetConfig;
use super::tensor::Tensor;

/// Upper bound on arena slots (the resnet live set is 3; 8 leaves room
/// for deeper topologies without a heap-allocated slot table on the hot
/// path).
const MAX_SLOTS: usize = 8;

/// One node of the net-level dataflow graph, for liveness analysis:
/// which arena values it reads (input + residual) and which it writes.
/// External tensors (the model input and the head outputs) are not
/// arena values and appear as `None`/absent.
#[derive(Debug, Clone)]
pub(crate) struct OpSpec {
    pub reads: Vec<usize>,
    pub write: Option<usize>,
}

/// Linear-scan liveness: assign every value an arena slot, recycling a
/// slot the moment the op performing the value's **last read** retires.
/// The written value's slot is allocated *before* this op's dying reads
/// are freed, so an op's output can never alias one of its live inputs.
/// Returns `(slot_of_value, slot_count)`.
pub(crate) fn assign_slots(n_values: usize, ops: &[OpSpec]) -> (Vec<usize>, usize) {
    let mut last_read = vec![usize::MAX; n_values];
    for (i, op) in ops.iter().enumerate() {
        for &v in &op.reads {
            last_read[v] = i;
        }
    }
    let mut slot_of = vec![usize::MAX; n_values];
    let mut free: Vec<usize> = Vec::new();
    let mut n_slots = 0usize;
    for (i, op) in ops.iter().enumerate() {
        if let Some(v) = op.write {
            // Allocate first (never reuse a slot this op still reads),
            // preferring the smallest free slot for determinism.
            let slot = match free.iter().enumerate().min_by_key(|&(_, &s)| s) {
                Some((at, _)) => free.swap_remove(at),
                None => {
                    n_slots += 1;
                    n_slots - 1
                }
            };
            slot_of[v] = slot;
            if last_read[v] == usize::MAX {
                // Dead store (no consumer): the slot frees immediately.
                free.push(slot);
            }
        }
        for &v in &op.reads {
            if last_read[v] == i {
                free.push(slot_of[v]);
            }
        }
    }
    (slot_of, n_slots)
}

/// Where an op reads its primary input from.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Src {
    /// The external model input `x` (`(N, 1, W)`).
    Input,
    /// An arena value (`(N, ch, W)`).
    Val(usize),
}

/// Where an op writes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Dst {
    Val(usize),
    Den,
    Logits,
}

/// A per-layer op: pad `src`, run the layer's cached
/// [`crate::conv1d::ConvPlan`] with its fused epilogue, mask the tail.
/// The conformance-reference path, and the path heads always take.
#[derive(Debug, Clone, Copy)]
struct LayerOp {
    layer: usize,
    src: Src,
    /// Arena value supplying the residual (when the layer's post-ops
    /// carry one).
    residual: Option<usize>,
    dst: Dst,
}

/// One fused stage: a conv consuming the previous stage's padded strip.
#[derive(Debug, Clone)]
struct Stage {
    layer: usize,
    c: usize,
    k: usize,
    /// Offset of this stage's `(S,K,C)` weights in the concatenated
    /// `w_skc` buffer.
    w_off: usize,
    /// Offset of this stage's bias in the concatenated bias buffer.
    b_off: usize,
    /// Tap offsets into the stage weights: `a_offs[s] = s·K·C`.
    a_offs: Vec<usize>,
}

/// A fused conv→conv chain: `stages` execute block-wise per image, with
/// intermediates living in per-worker padded strips, never the arena.
#[derive(Debug, Clone)]
struct Chain {
    stages: Vec<Stage>,
    src: Src,
    dst: usize,
}

/// The compiled program: fused chains (referenced by index) plus the
/// per-layer ops (all layers when unfused; only the heads when fused).
#[derive(Debug, Clone)]
enum NetOp {
    Layer(LayerOp),
    Chain(usize),
}

/// Knobs a plan was compiled against (rebuild when any changes).
#[derive(Debug, Clone, Copy, PartialEq)]
struct PlanKey {
    n: usize,
    w: usize,
    fuse: bool,
    backend: Backend,
    precision: Precision,
    threads: usize,
    autotune: bool,
    inference: bool,
}

/// A compiled net-level execution plan for one `(N, W)` shape: the
/// liveness-analyzed activation arena plus (when active) the fused
/// chain schedule. Built once per shape by the net's warm-up or first
/// inference, then executed allocation-free.
pub struct NetPlan {
    cfg: NetConfig,
    key: PlanKey,
    /// Same-pad geometry shared by every layer (`(S-1)·d` split).
    left: usize,
    right: usize,
    ops: Vec<NetOp>,
    chains: Vec<Chain>,
    /// Arena slot of each program value.
    slot_of: Vec<usize>,
    n_slots: usize,
    fused_active: bool,
    // ---- persistent buffers (allocated at build, reused forever) ----
    /// The activation arena: `n_slots` slots of `(N, ch, W)` each.
    arena: Vec<f32>,
    /// Shared pad staging for per-layer ops: `(N, ch, W + l + r)`.
    pad: Vec<f32>,
    /// Per-worker fused strips: `workers × strips_per_chain × ch × wp`.
    strips: Vec<f32>,
    /// bf16 operand twins of the strips (empty under f32).
    twins: Vec<Bf16>,
    /// Block tap-offset table: `b_offs[blk·S + s] = blk·64 + s·d` —
    /// read-only at execute time, shared by every worker.
    b_offs: Vec<usize>,
    /// Concatenated `(S,K,C)` weights of the fused stages, re-synced
    /// from the layers on every execute (so weight updates never go
    /// stale), plus their bf16 twins and biases.
    w_skc: Vec<f32>,
    w_bf16: Vec<Bf16>,
    bias: Vec<f32>,
    strips_per_chain: usize,
}

/// Build the per-layer (arena) program for the resnet topology.
/// Values: `h_0 = 0`, then per block `b`: `r_b = 2b+1`, `h_{b+1} = 2b+2`.
fn layer_program(cfg: &NetConfig) -> (Vec<LayerOp>, usize) {
    let nb = cfg.n_blocks;
    let mut ops = Vec::with_capacity(2 * nb + 3);
    ops.push(LayerOp {
        layer: 0,
        src: Src::Input,
        residual: None,
        dst: Dst::Val(0),
    });
    for b in 0..nb {
        let h = 2 * b;
        ops.push(LayerOp {
            layer: 1 + 2 * b,
            src: Src::Val(h),
            residual: None,
            dst: Dst::Val(h + 1),
        });
        ops.push(LayerOp {
            layer: 2 + 2 * b,
            src: Src::Val(h + 1),
            residual: Some(h),
            dst: Dst::Val(h + 2),
        });
    }
    let last = 2 * nb;
    ops.push(LayerOp {
        layer: 1 + 2 * nb,
        src: Src::Val(last),
        residual: None,
        dst: Dst::Den,
    });
    ops.push(LayerOp {
        layer: 2 + 2 * nb,
        src: Src::Val(last),
        residual: None,
        dst: Dst::Logits,
    });
    (ops, 2 * nb + 1)
}

/// Fused-chain layer groups: `[stem, c1_0, c2_0]` then `[c1_b, c2_b]`
/// per later block. Heads always stay per-layer (their `K = 1` output
/// is the external result, not a strip).
fn chain_groups(cfg: &NetConfig) -> Vec<Vec<usize>> {
    let nb = cfg.n_blocks;
    if nb == 0 {
        return vec![vec![0]];
    }
    let mut groups = vec![vec![0, 1, 2]];
    for b in 1..nb {
        groups.push(vec![1 + 2 * b, 2 + 2 * b]);
    }
    groups
}

fn op_specs_layers(ops: &[LayerOp]) -> Vec<OpSpec> {
    ops.iter()
        .map(|op| {
            let mut reads = Vec::new();
            if let Src::Val(v) = op.src {
                reads.push(v);
            }
            if let Some(v) = op.residual {
                reads.push(v);
            }
            OpSpec {
                reads,
                write: match op.dst {
                    Dst::Val(v) => Some(v),
                    _ => None,
                },
            }
        })
        .collect()
}

impl NetPlan {
    /// Compile the net for shape `(n, w)` against the layers' current
    /// execution knobs. `fuse` requests block-wise chain fusion; it
    /// engages only on the pinned BRGEMM backend (f32 or bf16, no
    /// autotuner — the tuner may pick a non-BRGEMM kernel per layer),
    /// falling back to the per-layer arena program otherwise.
    pub fn build(cfg: NetConfig, convs: &[ConvSame], n: usize, w: usize, fuse: bool) -> NetPlan {
        assert!(n > 0 && w > 0, "net plan needs a nonzero shape");
        assert_eq!(convs.len(), 2 * cfg.n_blocks + 3, "topology mismatch");
        let lead = &convs[0].conv;
        let key = PlanKey {
            n,
            w,
            fuse,
            backend: lead.backend,
            precision: lead.precision,
            threads: lead.threads,
            autotune: lead.autotune,
            inference: lead.inference,
        };
        let fused_active = fuse
            && lead.backend == Backend::Brgemm
            && !lead.autotune
            && matches!(lead.precision, Precision::F32 | Precision::Bf16);
        let (left, right) = ConvParams::same_pad(cfg.filter_size, cfg.dilation);
        let wp = w + left + right;
        let ch = cfg.channels;
        let bf16 = fused_active && key.precision == Precision::Bf16;

        let (chains, ops, slot_of, n_slots, strips_per_chain, w_len, b_len) = if fused_active {
            let groups = chain_groups(&cfg);
            // Chain value v feeds chain v+1; the last value feeds both
            // heads. Per-chain intermediates live in strips, not slots.
            let n_vals = groups.len();
            let mut w_len = 0usize;
            let mut b_len = 0usize;
            let mut chains = Vec::with_capacity(n_vals);
            for (ci, layers) in groups.iter().enumerate() {
                let mut stages = Vec::with_capacity(layers.len());
                for &l in layers {
                    let lc = &convs[l].conv;
                    stages.push(Stage {
                        layer: l,
                        c: lc.c,
                        k: lc.k,
                        w_off: w_len,
                        b_off: b_len,
                        a_offs: (0..lc.s).map(|is| is * lc.k * lc.c).collect(),
                    });
                    w_len += lc.s * lc.k * lc.c;
                    b_len += lc.k;
                }
                chains.push(Chain {
                    stages,
                    src: if ci == 0 { Src::Input } else { Src::Val(ci - 1) },
                    dst: ci,
                });
            }
            let mut specs: Vec<OpSpec> = chains
                .iter()
                .map(|c| OpSpec {
                    reads: match c.src {
                        Src::Val(v) => vec![v],
                        Src::Input => vec![],
                    },
                    write: Some(c.dst),
                })
                .collect();
            let last = n_vals - 1;
            let nb = cfg.n_blocks;
            let mut ops: Vec<NetOp> = (0..chains.len()).map(NetOp::Chain).collect();
            for head in [1 + 2 * nb, 2 + 2 * nb] {
                specs.push(OpSpec {
                    reads: vec![last],
                    write: None,
                });
                ops.push(NetOp::Layer(LayerOp {
                    layer: head,
                    src: Src::Val(last),
                    residual: None,
                    dst: if head == 1 + 2 * nb {
                        Dst::Den
                    } else {
                        Dst::Logits
                    },
                }));
            }
            let (slot_of, n_slots) = assign_slots(n_vals, &specs);
            let strips = chains.iter().map(|c| c.stages.len()).max().unwrap_or(1);
            (chains, ops, slot_of, n_slots, strips, w_len, b_len)
        } else {
            let (lops, n_vals) = layer_program(&cfg);
            let specs = op_specs_layers(&lops);
            let (slot_of, n_slots) = assign_slots(n_vals, &specs);
            let ops = lops.into_iter().map(NetOp::Layer).collect();
            (Vec::new(), ops, slot_of, n_slots, 0, 0, 0)
        };
        assert!(n_slots <= MAX_SLOTS, "live set exceeds the slot table");

        let workers = key.threads.max(1).min(n.max(1));
        let strip_elems = workers * strips_per_chain * ch * wp;
        let blocks = w.div_ceil(WIDTH_BLOCK);
        let s = cfg.filter_size;
        let mut b_offs = vec![0usize; blocks * s];
        for blk in 0..blocks {
            for is in 0..s {
                b_offs[blk * s + is] = blk * WIDTH_BLOCK + is * cfg.dilation;
            }
        }
        NetPlan {
            cfg,
            key,
            left,
            right,
            ops,
            chains,
            slot_of,
            n_slots,
            fused_active,
            arena: vec![0.0; n_slots * n * ch * w],
            pad: vec![0.0; n * ch * wp],
            strips: vec![0.0; strip_elems],
            twins: vec![Bf16::ZERO; if bf16 { strip_elems } else { 0 }],
            b_offs,
            w_skc: vec![0.0; w_len],
            w_bf16: vec![Bf16::ZERO; if bf16 { w_len } else { 0 }],
            bias: vec![0.0; b_len],
            strips_per_chain,
        }
    }

    /// Does this plan still match the shape and the layers' knobs?
    pub fn matches(&self, convs: &[ConvSame], n: usize, w: usize, fuse: bool) -> bool {
        let lead = &convs[0].conv;
        self.key
            == PlanKey {
                n,
                w,
                fuse,
                backend: lead.backend,
                precision: lead.precision,
                threads: lead.threads,
                autotune: lead.autotune,
                inference: lead.inference,
            }
    }

    /// Is block-wise chain fusion engaged (vs the per-layer arena
    /// program)?
    pub fn fused_active(&self) -> bool {
        self.fused_active
    }

    /// Arena slots the liveness analysis settled on (3 for the resnet
    /// per-layer program, ≤ 2 for the fused program).
    pub fn slot_count(&self) -> usize {
        self.n_slots
    }

    /// Bytes of persistent activation storage this plan holds: arena
    /// slots + pad staging + fused strips (and their bf16 twins). The
    /// quantity the bench compares against
    /// [`Self::per_layer_activation_bytes`].
    pub fn activation_bytes(&self) -> usize {
        4 * (self.arena.len() + self.pad.len() + self.strips.len()) + 2 * self.twins.len()
    }

    /// Activation bytes the pre-arena design held resident for the same
    /// shape: every layer's private pad staging `(N, C, wp)` plus its
    /// output tensor `(N, K, W)`.
    pub fn per_layer_activation_bytes(cfg: &NetConfig, n: usize, w: usize) -> usize {
        let (l, r) = ConvParams::same_pad(cfg.filter_size, cfg.dilation);
        let wp = w + l + r;
        let ch = cfg.channels;
        let layer = |c: usize, k: usize| n * (c * wp + k * w) * 4;
        let mut total = layer(1, ch); // stem
        for _ in 0..cfg.n_blocks {
            total += 2 * layer(ch, ch);
        }
        total + 2 * layer(ch, 1) // heads
    }

    /// Which per-layer plans the net still needs under this program —
    /// every layer when unfused, only the heads when fused (fused-chain
    /// layers never build a [`crate::conv1d::ConvPlan`]).
    pub fn per_layer_indices(&self) -> Vec<usize> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                NetOp::Layer(l) => Some(l.layer),
                NetOp::Chain(_) => None,
            })
            .collect()
    }

    /// Execute the compiled program. `x` is `(N, 1, W)`; `den`/`logits`
    /// are overwritten `(N, 1, W)` head outputs. `widths` enables
    /// per-row tail masking (bucket invariance): each row's columns
    /// `widths[i]..W` are re-zeroed at every producer boundary, exactly
    /// like the per-layer `infer_masked`. Heads are never masked.
    ///
    /// Zero heap allocations in the steady state (with `threads ≤ 1`
    /// end-to-end; thread spawns are the only exception, same as every
    /// kernel path).
    pub fn execute(
        &mut self,
        convs: &[ConvSame],
        x: &Tensor,
        widths: Option<&[usize]>,
        den: &mut Tensor,
        logits: &mut Tensor,
    ) -> Result<(), PlanError> {
        let (n, w) = (self.key.n, self.key.w);
        let ch = self.cfg.channels;
        assert_eq!((x.n, x.c, x.w), (n, 1, w), "input shape vs plan");
        assert_eq!((den.n, den.c, den.w), (n, 1, w), "denoised shape vs plan");
        assert_eq!(
            (logits.n, logits.c, logits.w),
            (n, 1, w),
            "logits shape vs plan"
        );
        if let Some(ws) = widths {
            assert_eq!(ws.len(), n, "one native width per row");
            assert!(ws.iter().all(|&v| v <= w), "native width exceeds plan width");
        }
        self.sync_fused_weights(convs);

        // Split the borrows: arena slots are handed out as disjoint
        // `&mut` chunks while the chain scratch stays independently
        // reachable.
        let NetPlan {
            ref cfg,
            ref key,
            left,
            right,
            ref ops,
            ref chains,
            ref slot_of,
            n_slots,
            ref mut arena,
            ref mut pad,
            ref mut strips,
            ref mut twins,
            ref b_offs,
            ref w_skc,
            ref w_bf16,
            ref bias,
            strips_per_chain,
            ..
        } = *self;
        let wp = w + left + right;
        let slot_sz = n * ch * w;
        let mut chunks = arena.chunks_mut(slot_sz.max(1));
        let mut slots: [Option<&mut [f32]>; MAX_SLOTS] = [const { None }; MAX_SLOTS];
        for slot in slots.iter_mut().take(n_slots) {
            *slot = chunks.next();
        }

        for op in ops {
            match op {
                NetOp::Layer(op) => {
                    let op = *op;
                    let lc = &convs[op.layer].conv;
                    let (c, k) = (lc.c, lc.k);
                    {
                        let src: &[f32] = match op.src {
                            Src::Input => &x.data,
                            Src::Val(v) => slots[slot_of[v]]
                                .as_deref()
                                .expect("source slot resident"),
                        };
                        pad_width_into(src, n, c, w, left, right, &mut pad[..n * c * wp]);
                    }
                    let res_slot = op.residual.map(|v| slot_of[v]);
                    match op.dst {
                        Dst::Val(v) => {
                            let ds = slot_of[v];
                            let out = slots[ds].take().expect("dst slot resident");
                            {
                                let res = res_slot.map(|s| {
                                    slots[s].as_deref().expect("residual slot resident")
                                });
                                out.fill(0.0);
                                lc.try_forward_post_into(&pad[..n * c * wp], res, n, wp, out)?;
                            }
                            if let Some(ws) = widths {
                                mask_rows(out, n, k, w, ws);
                            }
                            slots[ds] = Some(out);
                        }
                        Dst::Den | Dst::Logits => {
                            let out: &mut [f32] = if matches!(op.dst, Dst::Den) {
                                &mut den.data
                            } else {
                                &mut logits.data
                            };
                            let res = res_slot
                                .map(|s| slots[s].as_deref().expect("residual slot resident"));
                            out.fill(0.0);
                            lc.try_forward_post_into(&pad[..n * c * wp], res, n, wp, out)?;
                        }
                    }
                }
                NetOp::Chain(ci) => {
                    let chain = &chains[*ci];
                    let ds = slot_of[chain.dst];
                    let out = slots[ds].take().expect("chain dst slot resident");
                    {
                        let src: &[f32] = match chain.src {
                            Src::Input => &x.data,
                            Src::Val(v) => slots[slot_of[v]]
                                .as_deref()
                                .expect("chain source resident"),
                        };
                        run_chain(
                            convs,
                            chain,
                            ChainGeom {
                                n,
                                w,
                                left,
                                right,
                                ch,
                                threads: key.threads,
                                strips_per_chain,
                                s: cfg.filter_size,
                            },
                            src,
                            widths,
                            out,
                            strips,
                            twins,
                            b_offs,
                            w_skc,
                            w_bf16,
                            bias,
                        );
                    }
                    slots[ds] = Some(out);
                }
            }
        }
        Ok(())
    }

    /// Refresh the fused stages' packed weights/biases from the layers
    /// (a relayout copy — no allocation), so optimiser steps or direct
    /// bias mutation can never serve stale parameters.
    fn sync_fused_weights(&mut self, convs: &[ConvSame]) {
        let bf16 = !self.w_bf16.is_empty();
        for chain in &self.chains {
            for st in &chain.stages {
                let lc = &convs[st.layer].conv;
                let len = lc.s * st.k * st.c;
                kcs_to_skc_into(
                    lc.weights(),
                    st.k,
                    st.c,
                    lc.s,
                    &mut self.w_skc[st.w_off..st.w_off + len],
                );
                self.bias[st.b_off..st.b_off + st.k].copy_from_slice(&lc.bias);
                if bf16 {
                    to_bf16_into(
                        &self.w_skc[st.w_off..st.w_off + len],
                        &mut self.w_bf16[st.w_off..st.w_off + len],
                    );
                }
            }
        }
    }
}

/// Geometry a fused chain executes under (hoisted out of [`NetPlan`] so
/// the executor borrows only the buffers it needs).
#[derive(Clone, Copy)]
struct ChainGeom {
    n: usize,
    w: usize,
    left: usize,
    right: usize,
    ch: usize,
    threads: usize,
    strips_per_chain: usize,
    s: usize,
}

/// Execute one fused chain for every image: `src` is the chain input
/// `(N, c0, W)` (unpadded), `out` the destination slot `(N, k_last, W)`.
/// Each worker owns `strips_per_chain` padded strips (plus bf16 twins);
/// the per-image demand-driven schedule keeps every stage at most a
/// block-plus-halo ahead of its consumer.
#[allow(clippy::too_many_arguments)]
fn run_chain(
    convs: &[ConvSame],
    chain: &Chain,
    g: ChainGeom,
    src: &[f32],
    widths: Option<&[usize]>,
    out: &mut [f32],
    strips: &mut [f32],
    twins: &mut [Bf16],
    b_table: &[usize],
    w_skc: &[f32],
    w_bf16: &[Bf16],
    bias: &[f32],
) {
    let (n, w, l, r) = (g.n, g.w, g.left, g.right);
    let wp = w + l + r;
    let strip_sz = g.ch * wp;
    let worker_sz = g.strips_per_chain * strip_sz;
    let m = chain.stages.len();
    let c0 = chain.stages[0].c;
    let k_last = chain.stages[m - 1].k;
    let bf16 = !twins.is_empty();
    let uks = simd::active();
    debug_assert_eq!(out.len(), n * k_last * w);
    debug_assert_eq!(src.len(), n * c0 * w);

    par_batch_chunks_scratch(
        out,
        k_last * w,
        strips,
        worker_sz,
        twins,
        if bf16 { worker_sz } else { 0 },
        g.threads,
        |i, out_row, strips, twins| {
            // Stage 0 input: this image's chain source, padded. The
            // deeper strips' pad columns are structurally zero (never
            // written, zero since allocation).
            pad_width_into(
                &src[i * c0 * w..(i + 1) * c0 * w],
                1,
                c0,
                w,
                l,
                r,
                &mut strips[..c0 * wp],
            );
            if bf16 {
                to_bf16_into(&strips[..c0 * wp], &mut twins[..c0 * wp]);
            }
            let native = widths.map_or(w, |ws| ws[i]);
            // Demand-driven schedule: `done[j]` = output columns stage
            // j has produced. The deepest stage pulls; each producer
            // covers its consumer's next block plus the right halo
            // (`min(W, pos + nb + r)` — the same reach arithmetic as
            // `receptive_field_reach`). Left-halo columns were produced
            // by earlier blocks (left-to-right order) or are structural
            // pad zeros.
            let mut done = [0usize; 4];
            debug_assert!(m <= 4);
            loop {
                let mut demand = [0usize; 4];
                demand[m - 1] = w;
                for j in (0..m - 1).rev() {
                    demand[j] = if done[j + 1] >= w {
                        done[j] // consumer finished: stop producing
                    } else {
                        let nb = WIDTH_BLOCK.min(w - done[j + 1]);
                        (done[j + 1] + nb + r).min(w)
                    };
                }
                // Advance the shallowest lagging stage by one block.
                let Some(j) = (0..m).find(|&j| done[j] < demand[j]) else {
                    break;
                };
                let pos = done[j];
                let nb = WIDTH_BLOCK.min(w - pos);
                let st = &chain.stages[j];
                let ops = convs[st.layer].conv.post_ops;
                let bo = &b_table[(pos / WIDTH_BLOCK) * g.s..(pos / WIDTH_BLOCK) * g.s + g.s];
                // Split the strip stack: stages 0..=j readable, stage
                // j+1 writable.
                let (lo, hi) = strips.split_at_mut((j + 1) * strip_sz);
                let in_f32 = &lo[j * strip_sz..j * strip_sz + st.c * wp];
                let res_strip: Option<&[f32]> = if ops.residual {
                    debug_assert!(j >= 1, "residual stage needs an upstream strip");
                    Some(&lo[(j - 1) * strip_sz..(j - 1) * strip_sz + st.k * wp])
                } else {
                    None
                };
                // The same (m=K, n=nb, k=C, l_br=S) BRGEMM call as the
                // per-layer kernels; ldb/ldc only move loads/stores.
                if j == m - 1 {
                    if bf16 {
                        let tin = &twins[j * strip_sz..j * strip_sz + st.c * wp];
                        brgemm_bf16_with(
                            uks,
                            &w_bf16[st.w_off..],
                            &st.a_offs,
                            st.c,
                            tin,
                            bo,
                            wp,
                            &mut out_row[pos..],
                            w,
                            st.k,
                            nb,
                            st.c,
                            true,
                        );
                    } else {
                        brgemm_f32_with(
                            uks,
                            &w_skc[st.w_off..],
                            &st.a_offs,
                            st.c,
                            in_f32,
                            bo,
                            wp,
                            &mut out_row[pos..],
                            w,
                            st.k,
                            nb,
                            st.c,
                            true,
                        );
                    }
                    for ik in 0..st.k {
                        // Same is_none gate as the per-layer
                        // `apply_block`: a no-op epilogue must not even
                        // rewrite the block (1.0·v + 0.0 flips -0.0).
                        if !ops.is_none() {
                            let bias_k = bias[st.b_off + ik];
                            let res =
                                res_strip.map(|rs| &rs[ik * wp + l + pos..ik * wp + l + pos + nb]);
                            apply_segment(
                                &ops,
                                bias_k,
                                res,
                                &mut out_row[ik * w + pos..ik * w + pos + nb],
                            );
                        }
                        // Fusion-boundary tail masking (bucket
                        // invariance): re-zero the pad tail before
                        // anything downstream reads it.
                        if native < pos + nb {
                            let from = native.max(pos);
                            out_row[ik * w + from..ik * w + pos + nb].fill(0.0);
                        }
                    }
                } else {
                    let out_strip = &mut hi[..strip_sz];
                    if bf16 {
                        let tin = &twins[j * strip_sz..j * strip_sz + st.c * wp];
                        brgemm_bf16_with(
                            uks,
                            &w_bf16[st.w_off..],
                            &st.a_offs,
                            st.c,
                            tin,
                            bo,
                            wp,
                            &mut out_strip[l + pos..],
                            wp,
                            st.k,
                            nb,
                            st.c,
                            true,
                        );
                    } else {
                        brgemm_f32_with(
                            uks,
                            &w_skc[st.w_off..],
                            &st.a_offs,
                            st.c,
                            in_f32,
                            bo,
                            wp,
                            &mut out_strip[l + pos..],
                            wp,
                            st.k,
                            nb,
                            st.c,
                            true,
                        );
                    }
                    for ik in 0..st.k {
                        if !ops.is_none() {
                            let bias_k = bias[st.b_off + ik];
                            let at = ik * wp + l + pos;
                            let res = res_strip.map(|rs| &rs[at..at + nb]);
                            apply_segment(&ops, bias_k, res, &mut out_strip[at..at + nb]);
                        }
                        if native < pos + nb {
                            let from = native.max(pos);
                            out_strip[ik * wp + l + from..ik * wp + l + pos + nb].fill(0.0);
                        }
                    }
                    if bf16 {
                        // Narrow the freshly-produced (masked) block
                        // into the consumer's bf16 operand twin —
                        // element-wise rounding, so block-wise
                        // narrowing is bit-equal to the per-layer
                        // whole-row narrowing.
                        let twin_out = &mut twins[(j + 1) * strip_sz..(j + 2) * strip_sz];
                        for ik in 0..st.k {
                            let at = ik * wp + l + pos;
                            narrow_row_into(&out_strip[at..at + nb], &mut twin_out[at..at + nb]);
                        }
                    }
                }
                done[j] = pos + nb;
            }
        },
    );
}

/// Zero columns `widths[i]..w` of every `(row i, filter)` — the
/// per-layer tail re-zeroing of `infer_masked`, applied to an arena
/// slot.
fn mask_rows(t: &mut [f32], n: usize, k: usize, w: usize, widths: &[usize]) {
    for i in 0..n {
        let wv = widths[i];
        if wv >= w {
            continue;
        }
        for ik in 0..k {
            let base = (i * k + ik) * w;
            t[base + wv..base + w].fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn liveness_resnet_topology_needs_three_slots() {
        // stem → h0; c1 reads h0 → r0; c2 reads {r0, h0} → h1;
        // heads read h1. The residual read keeps h0 alive across c1.
        let (ops, n_vals) = layer_program(&NetConfig::tiny());
        let specs = op_specs_layers(&ops);
        let (slot_of, n_slots) = assign_slots(n_vals, &specs);
        assert_eq!(n_slots, 3);
        // h0 and r0 both die at c2; h1 must not alias either while they
        // are read.
        assert_eq!(slot_of[0], 0);
        assert_eq!(slot_of[1], 1);
        assert_eq!(slot_of[2], 2);
    }

    #[test]
    fn liveness_deeper_resnet_stays_at_three_slots() {
        let cfg = NetConfig {
            n_blocks: 5,
            ..NetConfig::tiny()
        };
        let (ops, n_vals) = layer_program(&cfg);
        let specs = op_specs_layers(&ops);
        let (slot_of, n_slots) = assign_slots(n_vals, &specs);
        assert_eq!(n_slots, 3, "live set is depth-independent");
        // Slots recycle: later blocks reuse the slots earlier values
        // vacated.
        assert!(slot_of[4] < 3 && slot_of[8] < 3);
    }

    #[test]
    fn liveness_without_residual_needs_two_slots() {
        // A plain chain a→b→c→out: each value dies as soon as the next
        // conv has consumed it, so two slots ping-pong.
        let specs = vec![
            OpSpec {
                reads: vec![],
                write: Some(0),
            },
            OpSpec {
                reads: vec![0],
                write: Some(1),
            },
            OpSpec {
                reads: vec![1],
                write: Some(2),
            },
            OpSpec {
                reads: vec![2],
                write: None,
            },
        ];
        let (slot_of, n_slots) = assign_slots(3, &specs);
        assert_eq!(n_slots, 2);
        assert_eq!(slot_of, vec![0, 1, 0]);
    }

    #[test]
    fn liveness_never_aliases_an_ops_output_with_its_live_inputs() {
        // The write is allocated before the dying reads free: b = f(a)
        // with a dying at that op still gets a distinct slot.
        let specs = vec![
            OpSpec {
                reads: vec![],
                write: Some(0),
            },
            OpSpec {
                reads: vec![0],
                write: Some(1),
            },
            OpSpec {
                reads: vec![1],
                write: None,
            },
        ];
        let (slot_of, n_slots) = assign_slots(2, &specs);
        assert_eq!(n_slots, 2);
        assert_ne!(slot_of[0], slot_of[1]);
    }

    #[test]
    fn fused_program_uses_fewer_slots_and_only_head_layer_plans() {
        use crate::model::AtacWorksNet;
        let cfg = NetConfig::tiny();
        let net = AtacWorksNet::init(cfg, 3);
        let plan = NetPlan::build(cfg, &net.convs, 2, 128, true);
        assert!(plan.fused_active());
        assert_eq!(plan.slot_count(), 1, "single chain output for nb=1");
        assert_eq!(
            plan.per_layer_indices(),
            vec![3, 4],
            "only the heads stay per-layer under fusion"
        );
        let unfused = NetPlan::build(cfg, &net.convs, 2, 128, false);
        assert!(!unfused.fused_active());
        assert_eq!(unfused.slot_count(), 3);
        assert_eq!(unfused.per_layer_indices().len(), 5);
        let per_layer = NetPlan::per_layer_activation_bytes(&cfg, 2, 128);
        assert!(plan.activation_bytes() < per_layer);
        assert!(unfused.activation_bytes() < per_layer);
    }

    #[test]
    fn plan_key_tracks_shape_and_knobs() {
        use crate::model::AtacWorksNet;
        let cfg = NetConfig::tiny();
        let mut net = AtacWorksNet::init(cfg, 3);
        let plan = NetPlan::build(cfg, &net.convs, 2, 128, true);
        assert!(plan.matches(&net.convs, 2, 128, true));
        assert!(!plan.matches(&net.convs, 2, 192, true));
        assert!(!plan.matches(&net.convs, 2, 128, false));
        net.set_backend(Backend::Im2col, 1);
        assert!(!plan.matches(&net.convs, 2, 128, true));
    }
}
