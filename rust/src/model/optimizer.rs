//! Optimisers over flat parameter vectors: Adam (the AtacWorks default)
//! and SGD with momentum. Matches python/compile/model.py's Adam exactly
//! (same β₁/β₂/ε and bias correction) so native and PJRT training agree.

/// Adam state over a flat parameter vector.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(param_len: usize, lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; param_len],
            v: vec![0.0; param_len],
            t: 0,
        }
    }

    /// One update: `params -= lr · m̂ / (√v̂ + ε)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }
}

/// SGD with classical momentum.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(param_len: usize, lr: f32, momentum: f32) -> Self {
        Sgd {
            lr,
            momentum,
            velocity: vec![0.0; param_len],
        }
    }

    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        for i in 0..params.len() {
            self.velocity[i] = self.momentum * self.velocity[i] - self.lr * grads[i];
            params[i] += self.velocity[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimises_quadratic() {
        // f(x) = Σ (x−3)², gradient 2(x−3).
        let mut x = vec![0.0f32; 4];
        let mut opt = Adam::new(4, 0.1);
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().map(|&v| 2.0 * (v - 3.0)).collect();
            opt.step(&mut x, &g);
        }
        for &v in &x {
            assert!((v - 3.0).abs() < 0.01, "x={v}");
        }
        assert_eq!(opt.steps_taken(), 500);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction makes the first step ≈ lr · sign(g).
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut x, &[0.37]);
        assert!((x[0] + 0.01).abs() < 1e-4, "x={}", x[0]);
    }

    #[test]
    fn sgd_with_momentum_accelerates() {
        let mut x_plain = vec![10.0f32];
        let mut x_mom = vec![10.0f32];
        let mut plain = Sgd::new(1, 0.01, 0.0);
        let mut mom = Sgd::new(1, 0.01, 0.9);
        for _ in 0..50 {
            let gp = [2.0 * x_plain[0]];
            plain.step(&mut x_plain, &gp);
            let gm = [2.0 * x_mom[0]];
            mom.step(&mut x_mom, &gm);
        }
        assert!(x_mom[0].abs() < x_plain[0].abs());
    }
}
