//! Split mixed-precision parameter store (DESIGN.md §6).
//!
//! The paper's BF16 training recipe (following the LIBXSMM convolution
//! line of work) is *split* SGD/Adam: the optimizer owns an FP32
//! **master** copy of every parameter and applies FP32 updates from FP32
//! gradient accumulation; the compute kernels see a BF16 **working** copy
//! re-rounded from the master after every step. Because our bf16 kernels
//! reproduce `VDPBF16PS` semantics (bf16 operands, f32 accumulate), the
//! working copy here is the bf16 *rounding* of the master, stored widened
//! to f32 — exactly the values the hardware instruction would read, with
//! no second rounding when the plan stages its bf16 weight layout.
//!
//! In [`Precision::F32`] mode the working copy is a plain mirror, so one
//! code path serves both precisions.
//!
//! ```
//! use dilconv1d::machine::Precision;
//! use dilconv1d::model::MasterWeights;
//!
//! let mut w = MasterWeights::new(vec![0.1f32; 4], Precision::Bf16);
//! // The optimizer updates the f32 master; the working copy re-rounds.
//! w.update(|master| {
//!     for p in master.iter_mut() {
//!         *p += 1.0e-3;
//!     }
//! });
//! assert!((w.master()[0] - 0.101).abs() < 1e-6); // full f32 step kept
//! assert_ne!(w.master()[0], w.working()[0]); // working is bf16-rounded
//! ```

use crate::conv1d::bf16::Bf16;
use crate::machine::Precision;

/// FP32 master parameters plus the (possibly bf16-rounded) working copy
/// the model replicas actually compute with.
#[derive(Debug, Clone)]
pub struct MasterWeights {
    precision: Precision,
    master: Vec<f32>,
    working: Vec<f32>,
}

impl MasterWeights {
    pub fn new(init: Vec<f32>, precision: Precision) -> MasterWeights {
        let mut w = MasterWeights {
            precision,
            working: vec![0.0; init.len()],
            master: init,
        };
        w.refresh();
        w
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn len(&self) -> usize {
        self.master.len()
    }

    pub fn is_empty(&self) -> bool {
        self.master.is_empty()
    }

    /// The FP32 master copy (what checkpoints store and the optimizer
    /// state tracks).
    pub fn master(&self) -> &[f32] {
        &self.master
    }

    /// The working copy the replicas load: bf16-rounded under
    /// [`Precision::Bf16`], identical to the master under
    /// [`Precision::F32`].
    pub fn working(&self) -> &[f32] {
        &self.working
    }

    /// Replace the master (e.g. from a checkpoint) and re-derive the
    /// working copy.
    pub fn set_master(&mut self, vals: &[f32]) {
        assert_eq!(vals.len(), self.master.len(), "param length mismatch");
        self.master.copy_from_slice(vals);
        self.refresh();
    }

    /// Apply an optimizer update to the FP32 master in place, then
    /// re-round the working copy — one split-optimizer step.
    pub fn update(&mut self, step: impl FnOnce(&mut Vec<f32>)) {
        step(&mut self.master);
        assert_eq!(
            self.master.len(),
            self.working.len(),
            "optimizer update must not resize the parameter vector"
        );
        self.refresh();
    }

    /// One-shot working-copy derivation: the bf16 rounding of `params`
    /// under [`Precision::Bf16`], a plain copy under [`Precision::F32`].
    /// The serving path loads replicas this way without keeping a master
    /// copy resident — inference never updates parameters, so the
    /// master/working split collapses to this single rounding
    /// (DESIGN.md §7).
    pub fn working_copy(params: &[f32], precision: Precision) -> Vec<f32> {
        match precision {
            Precision::F32 => params.to_vec(),
            Precision::Bf16 => params
                .iter()
                .map(|&p| Bf16::from_f32(p).to_f32())
                .collect(),
            // Int8 quantization is per-output-channel, which needs each
            // layer's (K, C, S) geometry — the flat vector has none. The
            // working copy stays f32; each plan quantizes its own weight
            // relayout in `derive_layouts` (conv1d/plan.rs).
            Precision::I8 => params.to_vec(),
        }
    }

    fn refresh(&mut self) {
        match self.precision {
            Precision::F32 | Precision::I8 => self.working.copy_from_slice(&self.master),
            Precision::Bf16 => {
                for (w, &m) in self.working.iter_mut().zip(&self.master) {
                    *w = Bf16::from_f32(m).to_f32();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_working_mirrors_master() {
        let mut w = MasterWeights::new(vec![0.1, -0.7, 3.25], Precision::F32);
        assert_eq!(w.master(), w.working());
        w.update(|m| m[1] = 0.123_456_7);
        assert_eq!(w.master(), w.working());
        assert_eq!(w.master()[1], 0.123_456_7);
    }

    #[test]
    fn bf16_working_is_rounded_but_master_keeps_small_updates() {
        // A step of 2^-12 is far below bf16 resolution at 1.0 (2^-8): the
        // working copy cannot represent it, the master must not lose it.
        let mut w = MasterWeights::new(vec![1.0f32], Precision::Bf16);
        assert_eq!(w.working()[0], 1.0);
        let step = (2.0f32).powi(-12);
        for _ in 0..32 {
            w.update(|m| m[0] += step);
        }
        assert_eq!(w.master()[0], 1.0 + 32.0 * step); // exact f32 sums
        // 32 steps add 2^-7 — exactly one bf16 ulp at 1.0: the working
        // copy eventually moves even though every single step rounds away.
        assert!(w.working()[0] > 1.0, "working copy never advanced");
        // And the working copy is always a bf16 value.
        assert_eq!(
            w.working()[0],
            Bf16::from_f32(w.working()[0]).to_f32(),
            "working copy must be bf16-representable"
        );
    }

    #[test]
    fn one_shot_working_copy_matches_the_split_store() {
        let params = vec![0.3f32, -1.7, 0.123_456_7, 42.5];
        for precision in [Precision::F32, Precision::Bf16] {
            let split = MasterWeights::new(params.clone(), precision);
            assert_eq!(
                MasterWeights::working_copy(&params, precision),
                split.working(),
                "{precision:?}"
            );
        }
        // Rounding is idempotent: a working copy round-trips unchanged.
        let once = MasterWeights::working_copy(&params, Precision::Bf16);
        assert_eq!(MasterWeights::working_copy(&once, Precision::Bf16), once);
    }

    #[test]
    fn set_master_refreshes_working() {
        let mut w = MasterWeights::new(vec![0.0; 2], Precision::Bf16);
        w.set_master(&[0.300_000_0, -0.300_000_0]);
        assert_eq!(w.working()[0], Bf16::from_f32(0.3).to_f32());
        assert_eq!(w.len(), 2);
        assert!(!w.is_empty());
    }
}
