//! Training losses: MSE (denoised signal) + BCE-with-logits (peak calls),
//! as in AtacWorks (paper Sec. 4.2), with analytic gradients for the
//! native engine's backward pass.

use crate::metrics::classification::sigmoid;

/// MSE value and gradient w.r.t. `pred`: `d/dpred mean((p−t)²) = 2(p−t)/n`.
pub fn mse_with_grad(pred: &[f32], target: &[f32]) -> (f64, Vec<f32>) {
    assert_eq!(pred.len(), target.len());
    let n = pred.len().max(1) as f64;
    let mut grad = vec![0.0f32; pred.len()];
    let mut loss = 0.0f64;
    for i in 0..pred.len() {
        let d = (pred[i] - target[i]) as f64;
        loss += d * d;
        grad[i] = (2.0 * d / n) as f32;
    }
    (loss / n, grad)
}

/// BCE-with-logits value and gradient w.r.t. logits:
/// `d/dz mean(bce) = (σ(z) − y)/n`.
pub fn bce_with_grad(logits: &[f32], labels: &[f32]) -> (f64, Vec<f32>) {
    assert_eq!(logits.len(), labels.len());
    let n = logits.len().max(1) as f64;
    let mut grad = vec![0.0f32; logits.len()];
    let mut loss = 0.0f64;
    for i in 0..logits.len() {
        let z = logits[i] as f64;
        let y = labels[i] as f64;
        loss += z.max(0.0) - z * y + (-z.abs()).exp().ln_1p();
        grad[i] = ((sigmoid(logits[i]) as f64 - y) / n) as f32;
    }
    (loss / n, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_check(f: impl Fn(&[f32]) -> f64, x: &[f32], grad: &[f32], eps: f32) {
        for i in 0..x.len() {
            let mut xp = x.to_vec();
            xp[i] += eps;
            let mut xm = x.to_vec();
            xm[i] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps as f64);
            assert!(
                (fd - grad[i] as f64).abs() < 1e-3 * (1.0 + grad[i].abs() as f64),
                "i={i}: fd {fd} vs {}",
                grad[i]
            );
        }
    }

    #[test]
    fn mse_gradient_matches_fd() {
        let pred = [0.5f32, -1.0, 2.0, 0.0];
        let target = [0.0f32, 1.0, 2.0, -0.5];
        let (loss, grad) = mse_with_grad(&pred, &target);
        assert!(loss > 0.0);
        fd_check(|p| mse_with_grad(p, &target).0, &pred, &grad, 1e-3);
    }

    #[test]
    fn bce_gradient_matches_fd() {
        let logits = [0.3f32, -2.0, 1.5, 0.0];
        let labels = [1.0f32, 0.0, 1.0, 0.0];
        let (loss, grad) = bce_with_grad(&logits, &labels);
        assert!(loss > 0.0);
        fd_check(|z| bce_with_grad(z, &labels).0, &logits, &grad, 1e-3);
    }

    #[test]
    fn perfect_predictions_have_small_loss() {
        let (l, g) = mse_with_grad(&[1.0, 2.0], &[1.0, 2.0]);
        assert_eq!(l, 0.0);
        assert!(g.iter().all(|&v| v == 0.0));
        let (l, _) = bce_with_grad(&[30.0, -30.0], &[1.0, 0.0]);
        assert!(l < 1e-8);
    }
}
