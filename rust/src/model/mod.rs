//! Native training engine: the AtacWorks-like dilated-conv ResNet
//! ([`resnet`]) built on the paper's conv kernels, with hand-written
//! fixed-topology autograd, losses ([`loss`]), optimisers
//! ([`optimizer`]) and the split mixed-precision parameter store
//! ([`precision`]). Mirrors python/compile/model.py layer-for-layer so
//! the flat parameter packing interoperates with the PJRT path.

pub mod layers;
pub mod loss;
pub mod netplan;
pub mod optimizer;
pub mod precision;
pub mod resnet;
pub mod tensor;

pub use layers::{ConvGrads, ConvSame};
pub use netplan::NetPlan;
pub use optimizer::{Adam, Sgd};
pub use precision::MasterWeights;
pub use resnet::{AtacWorksNet, Losses, NetConfig};
pub use tensor::Tensor;
