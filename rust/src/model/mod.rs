//! Native training engine: the AtacWorks-like dilated-conv ResNet
//! ([`resnet`]) built on the paper's conv kernels, with hand-written
//! fixed-topology autograd, losses ([`loss`]) and optimisers
//! ([`optimizer`]). Mirrors python/compile/model.py layer-for-layer so the
//! flat parameter packing interoperates with the PJRT path.

pub mod layers;
pub mod loss;
pub mod optimizer;
pub mod resnet;
pub mod tensor;

pub use layers::{ConvGrads, ConvSame};
pub use optimizer::{Adam, Sgd};
pub use resnet::{AtacWorksNet, Losses, NetConfig};
pub use tensor::Tensor;
