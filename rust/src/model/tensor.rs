//! Minimal `(N, C, W)` tensor for the native training engine.
//!
//! Deliberately tiny: contiguous `Vec<f32>` + shape, with only the ops the
//! AtacWorks network needs. The heavy lifting happens inside the conv1d
//! kernels; this type exists for shape-checked plumbing.

/// A row-major `(N, C, W)` tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub n: usize,
    pub c: usize,
    pub w: usize,
}

impl Tensor {
    pub fn zeros(n: usize, c: usize, w: usize) -> Self {
        Tensor {
            data: vec![0.0; n * c * w],
            n,
            c,
            w,
        }
    }

    pub fn from_vec(data: Vec<f32>, n: usize, c: usize, w: usize) -> Self {
        assert_eq!(data.len(), n * c * w, "shape/data mismatch");
        Tensor { data, n, c, w }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.n, self.c, self.w)
    }

    /// In-place ReLU; returns the activation mask for the backward pass.
    pub fn relu_inplace(&mut self) -> Vec<bool> {
        let mut mask = vec![false; self.data.len()];
        for (v, m) in self.data.iter_mut().zip(mask.iter_mut()) {
            if *v > 0.0 {
                *m = true;
            } else {
                *v = 0.0;
            }
        }
        mask
    }

    /// `self += other` (elementwise, shapes must match).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Apply a stored ReLU mask to a gradient (backward of `relu_inplace`).
    pub fn mask_gradient(grad: &mut [f32], mask: &[bool]) {
        assert_eq!(grad.len(), mask.len());
        for (g, &m) in grad.iter_mut().zip(mask) {
            if !m {
                *g = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_and_mask_roundtrip() {
        let mut t = Tensor::from_vec(vec![-1.0, 2.0, 0.0, 3.0], 1, 1, 4);
        let mask = t.relu_inplace();
        assert_eq!(t.data, vec![0.0, 2.0, 0.0, 3.0]);
        assert_eq!(mask, vec![false, true, false, true]);
        let mut g = vec![10.0, 10.0, 10.0, 10.0];
        Tensor::mask_gradient(&mut g, &mask);
        assert_eq!(g, vec![0.0, 10.0, 0.0, 10.0]);
    }

    #[test]
    fn add_assign() {
        let mut a = Tensor::from_vec(vec![1.0, 2.0], 1, 1, 2);
        let b = Tensor::from_vec(vec![3.0, 4.0], 1, 1, 2);
        a.add_assign(&b);
        assert_eq!(a.data, vec![4.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(vec![0.0; 5], 1, 2, 3);
    }
}
