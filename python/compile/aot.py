"""AOT compile path: lower L2/L1 computations to HLO-text artifacts.

Interchange format is HLO *text*, NOT `lowered.compile().serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the Rust
`xla` crate's bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids, so text round-trips cleanly.
(See /opt/xla-example/README.md and gen_hlo.py there.)

Emitted artifacts (artifacts/<name>.hlo.txt + artifacts/meta.json):

  conv_fwd_<tag>        single dilated-conv forward at paper shapes
  conv_bwd_data_<tag>   Algorithm-3 backward-data at the AtacWorks shape
  conv_bwd_weight_<tag> Algorithm-4 backward-weight at the AtacWorks shape
  eval_step_<model>     AtacWorks eval: (params, x) -> (denoised, peak_prob)
  train_step_<model>    AtacWorks Adam step: full state in/out
  grad_step_<model>     gradient-only step for the multi-socket coordinator
  params_<model>        initial packed parameters (raw little-endian f32)

`make artifacts` runs this once; the Rust binary is self-contained after.
"""

from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.conv1d import conv1d_fwd
from .kernels.conv1d_bwd import conv1d_bwd_data, conv1d_bwd_weight


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(shape, dtype="f32"):
    return {"dtype": dtype, "shape": list(shape)}


# ----------------------------------------------------------------- configs

# Conv artifact shapes: (tag, N, C, K, Q, S, d) — paper-named corners.
CONV_SHAPES = [
    ("atac", 4, 15, 15, 1024, 51, 8),    # AtacWorks layer (width-scaled)
    ("sq64", 2, 64, 64, 1024, 5, 1),     # Fig. 5 family
    ("wide", 1, 32, 32, 4096, 9, 4),     # Fig. 6 family
]

# Model variants lowered for the Rust runtime. Width is scaled from the
# paper's 60_000 so artifact compilation stays snappy; the native Rust
# engine runs the full-width configuration.
MODEL_VARIANTS = {
    # name: (channels, n_blocks, filter, dilation, N, W)
    "tiny": (15, 2, 51, 8, 2, 512),         # fast integration-test model
    "atacworks": (15, 11, 51, 8, 2, 1024),  # full 25-layer architecture
}


def emit_conv_artifacts(outdir: Path, meta: dict) -> None:
    for tag, n, c, k, q, s, d in CONV_SHAPES:
        w_in = q + (s - 1) * d
        x = _spec((n, c, w_in))
        w_skc = _spec((s, k, c))
        low = jax.jit(lambda xx, ww, dd=d: (conv1d_fwd(xx, ww, dd),)).lower(x, w_skc)
        name = f"conv_fwd_{tag}"
        (outdir / f"{name}.hlo.txt").write_text(to_hlo_text(low))
        meta[name] = {
            "kind": "conv_fwd",
            "params": {"n": n, "c": c, "k": k, "q": q, "s": s, "d": d, "w": w_in},
            "inputs": [_shape_entry((n, c, w_in)), _shape_entry((s, k, c))],
            "outputs": [_shape_entry((n, k, q))],
            "flops": ref.flops(n, c, k, q, s),
        }

    # Backward passes at the AtacWorks shape (runtime integration coverage;
    # the parameter sweeps use the native Rust kernels).
    tag, n, c, k, q, s, d = CONV_SHAPES[0]
    w_in = q + (s - 1) * d
    gout = _spec((n, k, q))
    w_kcs = _spec((k, c, s))
    x = _spec((n, c, w_in))

    low = jax.jit(lambda g, w: (conv1d_bwd_data(g, w, d, w_in),)).lower(gout, w_kcs)
    meta[f"conv_bwd_data_{tag}"] = {
        "kind": "conv_bwd_data",
        "params": {"n": n, "c": c, "k": k, "q": q, "s": s, "d": d, "w": w_in},
        "inputs": [_shape_entry((n, k, q)), _shape_entry((k, c, s))],
        "outputs": [_shape_entry((n, c, w_in))],
        "flops": ref.flops(n, c, k, q, s),
    }
    (outdir / f"conv_bwd_data_{tag}.hlo.txt").write_text(to_hlo_text(low))

    low = jax.jit(lambda g, xx: (conv1d_bwd_weight(g, xx, d, s),)).lower(gout, x)
    meta[f"conv_bwd_weight_{tag}"] = {
        "kind": "conv_bwd_weight",
        "params": {"n": n, "c": c, "k": k, "q": q, "s": s, "d": d, "w": w_in},
        "inputs": [_shape_entry((n, k, q)), _shape_entry((n, c, w_in))],
        "outputs": [_shape_entry((k, c, s))],
        "flops": ref.flops(n, c, k, q, s),
    }
    (outdir / f"conv_bwd_weight_{tag}.hlo.txt").write_text(to_hlo_text(low))


def emit_model_artifacts(outdir: Path, meta: dict, variants=None) -> None:
    for name, (ch, blocks, s, d, n, w) in MODEL_VARIANTS.items():
        if variants and name not in variants:
            continue
        cfg = M.ModelConfig(channels=ch, n_blocks=blocks, filter_size=s, dilation=d)
        spec, p_total = M.param_spec(cfg)
        pvec = _spec((p_total,))
        track = _spec((n, 1, w))
        scalar = _spec(())

        common = {
            "model": {
                "channels": ch,
                "n_blocks": blocks,
                "filter_size": s,
                "dilation": d,
                "n_conv_layers": cfg.n_conv_layers,
                "param_count": p_total,
                "param_spec": [
                    {"name": nm, "shape": list(shp), "offset": off, "size": sz}
                    for nm, shp, off, sz in spec
                ],
            },
            "batch": n,
            "width": w,
        }

        low = jax.jit(
            lambda p, m, v, t, x, c_, pk: M.train_step(p, m, v, t, x, c_, pk, cfg)
        ).lower(pvec, pvec, pvec, scalar, track, track, track)
        meta[f"train_step_{name}"] = {
            "kind": "train_step",
            **common,
            "inputs": [
                _shape_entry((p_total,)),
                _shape_entry((p_total,)),
                _shape_entry((p_total,)),
                _shape_entry(()),
                _shape_entry((n, 1, w)),
                _shape_entry((n, 1, w)),
                _shape_entry((n, 1, w)),
            ],
            "outputs": [
                _shape_entry((p_total,)),
                _shape_entry((p_total,)),
                _shape_entry((p_total,)),
                _shape_entry(()),
                _shape_entry(()),
                _shape_entry(()),
            ],
        }
        (outdir / f"train_step_{name}.hlo.txt").write_text(to_hlo_text(low))

        low = jax.jit(lambda p, x: M.eval_step(p, x, cfg)).lower(pvec, track)
        meta[f"eval_step_{name}"] = {
            "kind": "eval_step",
            **common,
            "inputs": [_shape_entry((p_total,)), _shape_entry((n, 1, w))],
            "outputs": [_shape_entry((n, 1, w)), _shape_entry((n, 1, w))],
        }
        (outdir / f"eval_step_{name}.hlo.txt").write_text(to_hlo_text(low))

        low = jax.jit(
            lambda p, x, c_, pk: M.grad_step(p, x, c_, pk, cfg)
        ).lower(pvec, track, track, track)
        meta[f"grad_step_{name}"] = {
            "kind": "grad_step",
            **common,
            "inputs": [
                _shape_entry((p_total,)),
                _shape_entry((n, 1, w)),
                _shape_entry((n, 1, w)),
                _shape_entry((n, 1, w)),
            ],
            "outputs": [
                _shape_entry((p_total,)),
                _shape_entry(()),
                _shape_entry(()),
                _shape_entry(()),
            ],
        }
        (outdir / f"grad_step_{name}.hlo.txt").write_text(to_hlo_text(low))

        # Initial parameters for the Rust side (raw little-endian f32).
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        flat = M.pack(params, cfg)
        np.asarray(flat, dtype="<f4").tofile(outdir / f"params_{name}.f32.bin")
        meta[f"params_{name}"] = {
            "kind": "params",
            "file": f"params_{name}.f32.bin",
            **common,
        }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default=os.environ.get("ARTIFACTS_DIR", "../artifacts"))
    ap.add_argument(
        "--only",
        choices=["conv", "model", "all"],
        default="all",
        help="restrict to conv or model artifacts",
    )
    ap.add_argument(
        "--variants",
        nargs="*",
        default=None,
        help="model variants to lower (default: all)",
    )
    args = ap.parse_args()
    outdir = Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    meta: dict = {}
    meta_path = outdir / "meta.json"
    if meta_path.exists():
        meta = json.loads(meta_path.read_text())

    if args.only in ("conv", "all"):
        emit_conv_artifacts(outdir, meta)
    if args.only in ("model", "all"):
        emit_model_artifacts(outdir, meta, args.variants)

    meta_path.write_text(json.dumps(meta, indent=2))
    print(f"wrote {len(meta)} artifact entries to {outdir}")


if __name__ == "__main__":
    main()
