"""Pallas backward kernels for the 1D dilated convolution layer.

Backward-data (paper Sec. 3.2, Algorithm 3)
-------------------------------------------
The paper observes the backward-data pass "is very similar to the forward
pass": relayout the weight from (K,C,S) to (S,C,K), zero-pad the output
gradient, and run the same width-blocked BRGEMM with the tap pointers walked
in reverse (B_ptrs[s] = &Grad_out[0, pos - (S-1-s)*d]).  We implement it
exactly that way — by *reusing the forward Pallas kernel*:

    dIn = conv1d_fwd( pad(Grad_out, (S-1)*d both sides),
                      weight relaid out to (S, C, K) with taps reversed, d )

which is algebraically identical to Algorithm 3 (substitute s' = S-1-s in
the convolution sum; the (S-1)*d pad realizes the negative pointer offsets).

Backward-weight (paper Sec. 3.3, Algorithm 4)
---------------------------------------------
A separate Pallas kernel: the grid runs over (batch, width-blocks) and every
step accumulates S small GEMMs

    Grad_w[s, :, :] += In[:, q0 + s*d : q0 + s*d + WB] @ Grad_out[:, q0:q0+WB]^T

into a single VMEM-resident (S, C, K) accumulator block whose BlockSpec maps
every grid step to the same block — the Pallas idiom for the paper's shared
weight-gradient tensor (which it calls out as the efficiency-limiting pass
because the accumulator must be shared across blocks/threads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .conv1d import DEFAULT_BLOCK, conv1d_fwd, _cdiv


def relayout_sck_flipped(w_kcs: jnp.ndarray) -> jnp.ndarray:
    """(K, C, S) -> (S, C, K) with the tap axis reversed.

    This is the paper's Sec. 3.2 backward-data weight layout; the flip
    realizes Algorithm 3's reversed pointer walk (S-1-s).
    """
    return jnp.transpose(w_kcs[:, :, ::-1], (2, 1, 0))


@functools.partial(jax.jit, static_argnames=("d", "W", "block"))
def conv1d_bwd_data(
    gout: jnp.ndarray, w_kcs: jnp.ndarray, d: int, W: int, block: int = DEFAULT_BLOCK
) -> jnp.ndarray:
    """Data gradient. gout: (N, K, Q); w_kcs: (K, C, S); returns (N, C, W)."""
    n, k, q = gout.shape
    s = w_kcs.shape[2]
    assert q == ref.out_width(W, s, d), (q, W, s, d)
    pad = (s - 1) * d
    gp = jnp.pad(gout, ((0, 0), (0, 0), (pad, pad)))
    w_sck = relayout_sck_flipped(w_kcs)
    return conv1d_fwd(gp, w_sck, d, block)


def _bwd_w_kernel(x_ref, g_ref, gw_ref, *, S: int, d: int, WB: int):
    """One (batch, width-block) grid step of Algorithm 4.

    x_ref : (1, C, Wp)  — full padded input row for this batch element
    g_ref : (1, K, WB)  — output-gradient block at offset qb*WB
    gw_ref: (S, C, K)   — shared accumulator (same block for every step)
    """
    nb = pl.program_id(0)
    qb = pl.program_id(1)

    @pl.when(jnp.logical_and(nb == 0, qb == 0))
    def _init():
        gw_ref[...] = jnp.zeros_like(gw_ref)

    q0 = qb * WB
    g_t = g_ref[0].T  # (WB, K)
    for s in range(S):
        panel = pl.load(x_ref, (0, slice(None), pl.dslice(q0 + s * d, WB)))  # (C, WB)
        gw_ref[s, :, :] += jax.lax.dot(panel, g_t, preferred_element_type=gw_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d", "S", "block"))
def conv1d_bwd_weight(
    gout: jnp.ndarray, x: jnp.ndarray, d: int, S: int, block: int = DEFAULT_BLOCK
) -> jnp.ndarray:
    """Weight gradient. gout: (N, K, Q); x: (N, C, W) pre-padded.

    Returns (K, C, S) — the framework-native layout; internally the
    accumulator lives in the paper's (S, C, K) layout.
    """
    n, k, q = gout.shape
    _, c, w_in = x.shape
    assert q == ref.out_width(w_in, S, d)
    qp = _cdiv(q, block) * block
    wp = qp + (S - 1) * d
    # Zero-pad both tensors: padded gradient columns are zero, so the extra
    # blocks contribute nothing to the accumulator.
    if qp > q:
        gout = jnp.pad(gout, ((0, 0), (0, 0), (0, qp - q)))
    if wp > w_in:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, wp - w_in)))
    acc_dtype = jnp.float32  # f32 accumulation even for bf16 inputs
    gw_sck = pl.pallas_call(
        functools.partial(_bwd_w_kernel, S=S, d=d, WB=block),
        grid=(n, qp // block),
        in_specs=[
            pl.BlockSpec((1, c, wp), lambda nb, qb: (nb, 0, 0)),
            pl.BlockSpec((1, k, block), lambda nb, qb: (nb, 0, qb)),
        ],
        out_specs=pl.BlockSpec((S, c, k), lambda nb, qb: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, c, k), acc_dtype),
        interpret=True,
    )(x.astype(acc_dtype), gout.astype(acc_dtype))
    return jnp.transpose(gw_sck, (2, 1, 0)).astype(x.dtype)  # (K, C, S)
