"""Pallas forward kernel for the 1D dilated convolution layer.

TPU re-think of the paper's BRGEMM algorithm (paper Sec. 3.1, Algorithm 2):

  * The paper blocks the output width into 64-element panels so that one
    GEMM dimension stays inside LIBXSMM's cache-friendly problem-size bound
    ((m*n*k)^(1/3) <= 64) and the working set stays L2-resident.
  * On TPU the analogous scratchpad is VMEM and the matmul engine is the
    MXU systolic array.  The Pallas grid runs over (batch, width-blocks);
    each grid step holds the whole (S, K, C) weight tensor plus one input
    panel in VMEM and issues S MXU matmuls (K,C) x (C,WB) accumulated into
    an f32 register/VMEM accumulator — literally BRGEMM with l_br = S
    (paper eq. 3), where the A_i pointer array is the tap index s and the
    B_i pointer array is the dilated panel offset q0 + s*d.
  * The weight is relaid out (K,C,S) -> (S,K,C) exactly as the paper does,
    so each tap's matmul is a contiguous (K,C) block.

VMEM footprint per grid step (f32):
    weight S*K*C*4  +  input panel C*(WB + (S-1)*d)*4  +  out block K*WB*4
For the paper's AtacWorks shape (C=K=15, S=51, d=8, WB=64) that is
~46 KB + ~28 KB + ~4 KB — far below the ~16 MB VMEM budget, leaving room
for double buffering; see DESIGN.md §8.

interpret=True throughout: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret mode lowers to plain HLO so the Rust runtime
can execute the same artifact (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 64  # paper's width block length (Sec. 3: "block length equal to 64")


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _fwd_kernel(x_ref, w_ref, o_ref, *, S: int, d: int, WB: int, acc_dtype):
    """One (batch, width-block) grid step.

    x_ref: (1, C, Wp)  — full padded input row for this batch element
    w_ref: (S, K, C)   — relaid-out weight, fully VMEM-resident
    o_ref: (1, K, WB)  — output block at width offset qb*WB
    """
    qb = pl.program_id(1)
    q0 = qb * WB
    k, c = w_ref.shape[1], w_ref.shape[2]
    acc = jnp.zeros((k, WB), acc_dtype)
    # BRGEMM with l_br = S: the s-loop is the batch-reduce dimension
    # (paper Algorithm 2, lines 3-7). Unrolled: S is a compile-time constant,
    # mirroring LIBXSMM's JIT specialization on the descriptor.
    for s in range(S):
        panel = pl.load(x_ref, (0, slice(None), pl.dslice(q0 + s * d, WB)))  # (C, WB)
        acc += jax.lax.dot(
            w_ref[s], panel, preferred_element_type=acc_dtype
        )
    o_ref[0, :, :] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d", "block"))
def conv1d_fwd(x: jnp.ndarray, w_skc: jnp.ndarray, d: int, block: int = DEFAULT_BLOCK):
    """Valid dilated conv forward. x: (N, C, W) pre-padded; w_skc: (S, K, C).

    Returns (N, K, Q) with Q = W - (S-1)*d.  Width is internally rounded up
    to a multiple of `block`; the pad region is computed on zero input and
    sliced away, so numerics match `ref.conv1d_ref` exactly.
    """
    n, c, w_in = x.shape
    s, k, _ = w_skc.shape
    q = ref.out_width(w_in, s, d)
    qp = _cdiv(q, block) * block
    wp = qp + (s - 1) * d
    if wp > w_in:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, wp - w_in)))
    grid = (n, qp // block)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, S=s, d=d, WB=block, acc_dtype=jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, wp), lambda nb, qb: (nb, 0, 0)),
            pl.BlockSpec((s, k, c), lambda nb, qb: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, block), lambda nb, qb: (nb, 0, qb)),
        out_shape=jax.ShapeDtypeStruct((n, k, qp), x.dtype),
        interpret=True,
    )(x, w_skc)
    return out[:, :, :q]


def relayout_skc(w_kcs: jnp.ndarray) -> jnp.ndarray:
    """Weight relayout (K, C, S) -> (S, K, C). Paper Sec. 3.1."""
    return jnp.transpose(w_kcs, (2, 0, 1))


def conv1d(x: jnp.ndarray, w_kcs: jnp.ndarray, d: int, block: int = DEFAULT_BLOCK):
    """Convenience wrapper taking the framework-native (K, C, S) layout."""
    return conv1d_fwd(x, relayout_skc(w_kcs), d, block)
