"""Pure-jnp/lax reference oracle for the 1D dilated convolution layer.

These are the ground-truth implementations that the Pallas kernels
(`conv1d.py`, `conv1d_bwd.py`) are validated against in pytest, and that the
Rust native kernels are validated against through golden files.

Conventions (paper, Sec. 2):
  input   In     : (N, C, W)   -- batch, channels, width (ALREADY padded)
  weight  Weight : (K, C, S)   -- filters, channels, filter width
  output  Out    : (N, K, Q)   with Q = W - (S-1)*d   ("valid" convolution)
  dilation d     : filter taps are applied to every d-th input element

`same`-padding wrappers pad the input with (S-1)*d zeros split across both
edges so that Q == W_unpadded, which is how the AtacWorks workload uses the
layer (paper Sec. 4.2: 50_000-wide segments padded to 60_000).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "out_width",
    "same_pad",
    "conv1d_ref",
    "conv1d_bwd_data_ref",
    "conv1d_bwd_weight_ref",
    "conv1d_vjp_ref",
    "flops",
]


def out_width(w: int, s: int, d: int) -> int:
    """Output width of a valid dilated 1D convolution. Paper eq. (2)."""
    q = w - (s - 1) * d
    if q <= 0:
        raise ValueError(f"input width {w} too small for S={s}, d={d}")
    return q


def same_pad(s: int, d: int) -> tuple[int, int]:
    """(left, right) zero padding so that Q == W."""
    total = (s - 1) * d
    return total // 2, total - total // 2


def flops(n: int, c: int, k: int, q: int, s: int) -> int:
    """MAC-based FLOP count of one pass (paper's efficiency denominator)."""
    return 2 * n * c * k * q * s


def conv1d_ref(x: jnp.ndarray, w: jnp.ndarray, d: int) -> jnp.ndarray:
    """Valid dilated 1D convolution via lax.conv_general_dilated.

    x: (N, C, W) pre-padded input; w: (K, C, S); returns (N, K, Q).
    Implements paper eq. (2): Out[k, q] = sum_c sum_s In[c, q + d*s] * W[k, c, s].
    """
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(1,),
        padding="VALID",
        rhs_dilation=(d,),
        dimension_numbers=("NCH", "OIH", "NCH"),
    )


def conv1d_bwd_data_ref(gout: jnp.ndarray, w: jnp.ndarray, d: int, W: int) -> jnp.ndarray:
    """Gradient of conv1d_ref w.r.t. x, computed with jax.vjp (exact oracle).

    gout: (N, K, Q); w: (K, C, S); returns (N, C, W).
    """
    n, k, q = gout.shape
    c = w.shape[1]
    x0 = jnp.zeros((n, c, W), gout.dtype)
    _, vjp = jax.vjp(lambda x: conv1d_ref(x, w, d), x0)
    return vjp(gout)[0]


def conv1d_bwd_weight_ref(gout: jnp.ndarray, x: jnp.ndarray, d: int, S: int) -> jnp.ndarray:
    """Gradient of conv1d_ref w.r.t. w; returns (K, C, S)."""
    k = gout.shape[1]
    c = x.shape[1]
    w0 = jnp.zeros((k, c, S), x.dtype)
    _, vjp = jax.vjp(lambda w: conv1d_ref(x, w, d), w0)
    return vjp(gout)[0]


def conv1d_vjp_ref(x: jnp.ndarray, w: jnp.ndarray, gout: jnp.ndarray, d: int):
    """(grad_x, grad_w) in one vjp call — used for end-to-end grad checks."""
    _, vjp = jax.vjp(lambda x_, w_: conv1d_ref(x_, w_, d), x, w)
    return vjp(gout)
