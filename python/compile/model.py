"""L2: AtacWorks-like 1D dilated-convolution ResNet in JAX.

This is the end-to-end workload of the paper's Sec. 4.2/4.4: a 25-layer 1D
CNN ("AtacWorks", Lal et al. 2019) that takes a noisy ATAC-seq coverage
track segment (N, 1, W) and produces

  * a denoised track      (N, 1, W)   — trained with MSE, and
  * peak-call logits      (N, 1, W)   — trained with binary cross-entropy.

Architecture (25 conv layers total, matching the paper's description that
"most convolution layers have 15 channels, 15 filters, a filter size of 51,
and a dilation of 8"):

  stem:        conv 1 -> ch                                     (1 layer)
  11 residual blocks: [conv ch->ch, ReLU, conv ch->ch] + skip   (22 layers)
  reg head:    conv ch -> 1                                     (1 layer)
  cls head:    conv ch -> 1                                     (1 layer)

Every conv is the paper's 1D dilated convolution, evaluated through the L1
Pallas kernels (conv1d.py / conv1d_bwd.py) wired up with jax.custom_vjp so
the backward pass uses the paper's Algorithm 3/4 kernels rather than XLA's
autodiff of the forward.

All functions here are pure and jit-lowerable; `aot.py` lowers the train and
eval steps to HLO text artifacts executed from the Rust runtime. Python
never runs at training time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.conv1d import conv1d_fwd, relayout_skc
from .kernels.conv1d_bwd import conv1d_bwd_data, conv1d_bwd_weight


# --------------------------------------------------------------------------
# Differentiable conv layer: Pallas forward, Pallas backward (Alg. 2/3/4)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def conv1d_layer(x: jnp.ndarray, w_kcs: jnp.ndarray, d: int) -> jnp.ndarray:
    """Valid dilated conv with paper-kernel forward AND backward passes."""
    return conv1d_fwd(x, relayout_skc(w_kcs), d)


def _conv1d_layer_fwd(x, w_kcs, d):
    # custom_vjp fwd takes args in primal positions; nondiff args (d) are
    # passed to the bwd rule as leading arguments.
    return conv1d_layer(x, w_kcs, d), (x, w_kcs)


def _conv1d_layer_bwd(d, res, gout):
    x, w_kcs = res
    s = w_kcs.shape[2]
    gx = conv1d_bwd_data(gout, w_kcs, d, x.shape[2])
    gw = conv1d_bwd_weight(gout, x, d, s)
    return gx, gw


conv1d_layer.defvjp(_conv1d_layer_fwd, _conv1d_layer_bwd)


def conv1d_same(x: jnp.ndarray, w_kcs: jnp.ndarray, bias: jnp.ndarray, d: int):
    """Same-padded conv + bias: Q == W. Bias add is the framework's job in
    the paper (Sec. 3: "we do not implement the bias calculation ... but
    instead use the framework's implementation"); here the framework is XLA."""
    s = w_kcs.shape[2]
    left, right = ref.same_pad(s, d)
    xp = jnp.pad(x, ((0, 0), (0, 0), (left, right)))
    out = conv1d_layer(xp, w_kcs, d)
    return out + bias[None, :, None]


# --------------------------------------------------------------------------
# Model definition
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """AtacWorks-like network hyperparameters (paper Sec. 4.2)."""

    channels: int = 15       # 15 for FP32 runs, 16 for BF16 runs (Sec. 4.4)
    n_blocks: int = 11       # 11 residual blocks -> 25 conv layers total
    filter_size: int = 51
    dilation: int = 8
    dtype: Any = jnp.float32

    @property
    def n_conv_layers(self) -> int:
        return 1 + 2 * self.n_blocks + 2  # stem + block convs + two heads

    def layer_shapes(self):
        """[(K, C, S)] for every conv layer, in parameter order."""
        ch, s = self.channels, self.filter_size
        shapes = [(ch, 1, s)]                        # stem
        for _ in range(self.n_blocks):
            shapes += [(ch, ch, s), (ch, ch, s)]     # residual block
        shapes += [(1, ch, s), (1, ch, s)]           # reg head, cls head
        return shapes


def init_params(key, cfg: ModelConfig):
    """He-initialised weights + zero biases, as a flat list of (w, b)."""
    params = []
    for shp in cfg.layer_shapes():
        key, sub = jax.random.split(key)
        k, c, s = shp
        fan_in = c * s
        w = jax.random.normal(sub, shp, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        params.append((w.astype(cfg.dtype), jnp.zeros((k,), cfg.dtype)))
    return params


def forward(params, x, cfg: ModelConfig):
    """x: (N, 1, W) noisy track -> (denoised (N,1,W), peak logits (N,1,W))."""
    d = cfg.dilation
    it = iter(params)
    w, b = next(it)
    h = jax.nn.relu(conv1d_same(x, w, b, d))                 # stem
    for _ in range(cfg.n_blocks):
        w1, b1 = next(it)
        w2, b2 = next(it)
        r = jax.nn.relu(conv1d_same(h, w1, b1, d))
        r = conv1d_same(r, w2, b2, d)
        h = jax.nn.relu(h + r)                               # residual + ReLU
    wr, br = next(it)
    wc, bc = next(it)
    denoised = conv1d_same(h, wr, br, d)
    logits = conv1d_same(h, wc, bc, d)
    return denoised, logits


# --------------------------------------------------------------------------
# Losses (paper Sec. 4.2: MSE for the denoised signal + BCE for peaks)
# --------------------------------------------------------------------------


def mse_loss(pred, target):
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - target.astype(jnp.float32)))


def bce_with_logits(logits, labels):
    """Numerically-stable binary cross entropy on logits."""
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def loss_fn(params, batch, cfg: ModelConfig, mse_weight: float = 1.0, bce_weight: float = 1.0):
    x, clean, peaks = batch
    denoised, logits = forward(params, x, cfg)
    l_mse = mse_loss(denoised, clean)
    l_bce = bce_with_logits(logits, peaks)
    return mse_weight * l_mse + bce_weight * l_bce, (l_mse, l_bce)


# --------------------------------------------------------------------------
# Flat parameter packing — the Rust runtime's ABI
# --------------------------------------------------------------------------
# The train/eval HLO artifacts take ONE flat f32 vector per state tensor
# (params, adam m, adam v) so the Rust side never has to mirror the pytree.


def param_spec(cfg: ModelConfig):
    """([(name, shape, offset, size)], total) for the flat packing."""
    spec = []
    off = 0
    for i, (k, c, s) in enumerate(cfg.layer_shapes()):
        for suffix, shape in (("w", (k, c, s)), ("b", (k,))):
            size = 1
            for dim in shape:
                size *= dim
            spec.append((f"conv{i}.{suffix}", shape, off, size))
            off += size
    return spec, off


def pack(params, cfg: ModelConfig) -> jnp.ndarray:
    flat = []
    for w, b in params:
        flat.append(jnp.ravel(w).astype(jnp.float32))
        flat.append(jnp.ravel(b).astype(jnp.float32))
    return jnp.concatenate(flat)


def unpack(flat: jnp.ndarray, cfg: ModelConfig):
    spec, _total = param_spec(cfg)
    params = []
    i = 0
    while i < len(spec):
        _, wshape, woff, wsize = spec[i]
        _, bshape, boff, bsize = spec[i + 1]
        w = jnp.reshape(flat[woff : woff + wsize], wshape).astype(cfg.dtype)
        b = jnp.reshape(flat[boff : boff + bsize], bshape).astype(cfg.dtype)
        params.append((w, b))
        i += 2
    return params


# --------------------------------------------------------------------------
# Adam optimiser + train / eval steps (the AOT entry points)
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def train_step(flat_params, m, v, step, x, clean, peaks, cfg: ModelConfig, lr: float = 2e-4):
    """One Adam step. All state is flat f32; returns new state + losses.

    Signature (the Rust-side ABI, see runtime/step.rs):
      in : params[f32 P], m[f32 P], v[f32 P], step[f32], x[N,1,W], clean[N,1,W], peaks[N,1,W]
      out: (params', m', v', loss, mse, bce)
    """

    def packed_loss(flat):
        l, aux = loss_fn(unpack(flat, cfg), (x, clean, peaks), cfg)
        return l, aux

    (loss, (l_mse, l_bce)), grads = jax.value_and_grad(packed_loss, has_aux=True)(
        flat_params
    )
    t = step + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * jnp.square(grads)
    mhat = m / (1.0 - jnp.power(ADAM_B1, t))
    vhat = v / (1.0 - jnp.power(ADAM_B2, t))
    new_params = flat_params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new_params, m, v, loss, l_mse, l_bce


def eval_step(flat_params, x, cfg: ModelConfig):
    """Inference: returns (denoised, peak probabilities)."""
    denoised, logits = forward(unpack(flat_params, cfg), x, cfg)
    return denoised, jax.nn.sigmoid(logits.astype(jnp.float32))


def grad_step(flat_params, x, clean, peaks, cfg: ModelConfig):
    """Gradient-only step (no optimiser) — used by the multi-socket
    coordinator, which all-reduces gradients across workers before applying
    the optimiser centrally (paper Sec. 4.5 data-parallel training)."""

    def packed_loss(flat):
        l, aux = loss_fn(unpack(flat, cfg), (x, clean, peaks), cfg)
        return l, aux

    (loss, (l_mse, l_bce)), grads = jax.value_and_grad(packed_loss, has_aux=True)(
        flat_params
    )
    return grads, loss, l_mse, l_bce
