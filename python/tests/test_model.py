"""L2 model tests: shapes, losses, gradients, parameter packing, and a
few optimisation steps (loss decreases) — the JAX side of the end-to-end
stack, mirrored by rust/tests/integration_training.rs on the native side.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.ModelConfig(channels=4, n_blocks=1, filter_size=9, dilation=2)


def _batch(cfg, n=2, w=128, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.poisson(k1, 0.3, (n, 1, w)).astype(jnp.float32)
    clean = jax.random.poisson(k2, 1.5, (n, 1, w)).astype(jnp.float32)
    peaks = (jax.random.uniform(k3, (n, 1, w)) < 0.15).astype(jnp.float32)
    return x, clean, peaks


def test_architecture_is_25_layers_at_paper_config():
    cfg = M.ModelConfig()
    assert cfg.n_conv_layers == 25
    shapes = cfg.layer_shapes()
    assert shapes[0] == (15, 1, 51)       # stem
    assert shapes[1] == (15, 15, 51)      # block conv
    assert shapes[-1] == (1, 15, 51)      # cls head
    assert len(shapes) == 25


def test_forward_shapes():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    x, _, _ = _batch(TINY)
    den, logits = M.forward(params, x, TINY)
    assert den.shape == x.shape
    assert logits.shape == x.shape


def test_loss_is_finite_and_composed():
    params = M.init_params(jax.random.PRNGKey(1), TINY)
    batch = _batch(TINY, seed=1)
    loss, (l_mse, l_bce) = M.loss_fn(params, batch, TINY)
    assert np.isfinite(float(loss))
    np.testing.assert_allclose(float(loss), float(l_mse) + float(l_bce), rtol=1e-6)


def test_pack_unpack_roundtrip():
    params = M.init_params(jax.random.PRNGKey(2), TINY)
    flat = M.pack(params, TINY)
    spec, total = M.param_spec(TINY)
    assert flat.shape == (total,)
    params2 = M.unpack(flat, TINY)
    for (w1, b1), (w2, b2) in zip(params, params2):
        np.testing.assert_array_equal(w1, w2)
        np.testing.assert_array_equal(b1, b2)
    # Spec offsets tile the vector exactly.
    assert spec[0][2] == 0
    assert sum(e[3] for e in spec) == total


def test_custom_vjp_matches_xla_autodiff():
    # The paper-kernel backward (Algorithms 3/4 via custom_vjp) must equal
    # XLA differentiating the forward definition.
    cfg = TINY
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    x, clean, peaks = _batch(cfg, n=1, w=96, seed=3)

    def loss_with_kernels(p):
        return M.loss_fn(p, (x, clean, peaks), cfg)[0]

    def loss_with_xla(p):
        # Re-express the conv through lax directly (no custom_vjp).
        from compile.kernels import ref

        d = cfg.dilation
        it = iter(p)

        def conv(h, w, b):
            s = w.shape[2]
            l, r = ref.same_pad(s, d)
            hp = jnp.pad(h, ((0, 0), (0, 0), (l, r)))
            return ref.conv1d_ref(hp, w, d) + b[None, :, None]

        w0, b0 = next(it)
        h = jax.nn.relu(conv(x, w0, b0))
        for _ in range(cfg.n_blocks):
            w1, b1 = next(it)
            w2, b2 = next(it)
            r_ = jax.nn.relu(conv(h, w1, b1))
            r_ = conv(r_, w2, b2)
            h = jax.nn.relu(h + r_)
        wr, br = next(it)
        wc, bc = next(it)
        den = conv(h, wr, br)
        logit = conv(h, wc, bc)
        return M.mse_loss(den, clean) + M.bce_with_logits(logit, peaks)

    g1 = jax.grad(loss_with_kernels)(params)
    g2 = jax.grad(loss_with_xla)(params)
    for (gw1, gb1), (gw2, gb2) in zip(g1, g2):
        np.testing.assert_allclose(gw1, gw2, rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(gb1, gb2, rtol=2e-3, atol=2e-4)


def test_train_step_decreases_loss():
    cfg = TINY
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    flat = M.pack(params, cfg)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    x, clean, peaks = _batch(cfg, n=2, w=96, seed=4)
    losses = []
    step = jnp.array(0.0)
    for i in range(5):
        flat, m, v, loss, _, _ = M.train_step(
            flat, m, v, step + i, x, clean, peaks, cfg, lr=1e-3
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_eval_step_probabilities():
    cfg = TINY
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    flat = M.pack(params, cfg)
    x, _, _ = _batch(cfg, seed=5)
    den, probs = M.eval_step(flat, x, cfg)
    assert den.shape == x.shape
    assert float(jnp.min(probs)) >= 0.0 and float(jnp.max(probs)) <= 1.0


def test_grad_step_matches_train_step_gradients():
    cfg = TINY
    params = M.init_params(jax.random.PRNGKey(6), cfg)
    flat = M.pack(params, cfg)
    x, clean, peaks = _batch(cfg, seed=6)
    grads, loss, l_mse, l_bce = M.grad_step(flat, x, clean, peaks, cfg)
    assert grads.shape == flat.shape
    assert np.isfinite(float(loss))
    # One Adam step with those grads equals train_step's update.
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    new_flat, _, _, loss2, _, _ = M.train_step(
        flat, m, v, jnp.array(0.0), x, clean, peaks, cfg
    )
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)
    mm = 0.1 * grads
    vv = 0.001 * jnp.square(grads)
    mhat = mm / (1 - 0.9)
    vhat = vv / (1 - 0.999)
    manual = flat - 2e-4 * mhat / (jnp.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(new_flat, manual, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("blocks", [1, 2])
def test_param_count_formula(blocks):
    cfg = M.ModelConfig(channels=6, n_blocks=blocks, filter_size=7, dilation=3)
    _, total = M.param_spec(cfg)
    expect = sum(k * c * s + k for (k, c, s) in cfg.layer_shapes())
    assert total == expect
