"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Covers the paper's parameter grid (Sec. 4.3): output width, channels,
filters, filter width, and dilation, for f32 and bf16, via both a curated
grid (paper-named shapes) and hypothesis-driven random sweeps.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv1d import conv1d, conv1d_fwd, relayout_skc
from compile.kernels.conv1d_bwd import (
    conv1d_bwd_data,
    conv1d_bwd_weight,
    relayout_sck_flipped,
)

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _mk(n, c, k, w, s, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (n, c, w), dtype)
    wt = _rand(k2, (k, c, s), dtype) * (1.0 / np.sqrt(c * s))
    q = ref.out_width(w, s, d)
    g = _rand(k3, (n, k, q), dtype)
    return x, wt, g, q


# ---------------------------------------------------------------- shape math


def test_out_width_valid():
    assert ref.out_width(60000, 51, 8) == 60000 - 50 * 8
    assert ref.out_width(17, 3, 3) == 11
    assert ref.out_width(5, 1, 16) == 5  # S=1: dilation irrelevant


def test_out_width_rejects_too_small():
    with pytest.raises(ValueError):
        ref.out_width(10, 5, 4)


def test_same_pad_splits_total():
    for s, d in [(51, 8), (5, 1), (9, 16), (1, 4), (2, 3)]:
        l, r = ref.same_pad(s, d)
        assert l + r == (s - 1) * d
        assert 0 <= l <= r


def test_flops_matches_paper_formula():
    # 2*N*C*K*Q*S MACs->FLOPs
    assert ref.flops(1, 15, 15, 1000, 51) == 2 * 15 * 15 * 1000 * 51


# ---------------------------------------------------------------- relayouts


def test_relayout_skc_roundtrip():
    w = jnp.arange(4 * 3 * 5, dtype=jnp.float32).reshape(4, 3, 5)
    skc = relayout_skc(w)
    assert skc.shape == (5, 4, 3)
    np.testing.assert_array_equal(np.transpose(skc, (1, 2, 0)), w)


def test_relayout_sck_flip():
    w = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    sck = relayout_sck_flipped(w)
    assert sck.shape == (4, 3, 2)
    for s in range(4):
        np.testing.assert_array_equal(sck[s], np.asarray(w)[:, :, 3 - s].T)


# ---------------------------------------------------------------- forward

PAPER_GRID = [
    # (n, c, k, q, s, d) — representative corners of Sec. 4.3's sweep sets
    (2, 15, 15, 128, 51, 8),   # AtacWorks layer shape (scaled width)
    (1, 64, 64, 256, 5, 1),    # Fig. 5 family
    (2, 32, 32, 200, 9, 4),    # Fig. 6 family
    (1, 1, 1, 64, 1, 1),       # degenerate minimum
    (1, 4, 8, 100, 15, 2),     # non-square C/K, Q not multiple of 64
    (3, 10, 16, 77, 21, 1),    # odd everything
    (1, 8, 4, 640, 25, 16),    # large dilation
    (2, 16, 16, 96, 2, 5),     # even-channel bf16-legal shape
]


@pytest.mark.parametrize("n,c,k,q,s,d", PAPER_GRID)
def test_forward_matches_ref(n, c, k, q, s, d):
    w_in = q + (s - 1) * d
    x, wt, _, _ = _mk(n, c, k, w_in, s, d)
    got = conv1d(x, wt, d)
    want = ref.conv1d_ref(x, wt, d)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block", [16, 64, 128])
def test_forward_block_size_invariance(block):
    x, wt, _, _ = _mk(2, 6, 7, 150 + 4 * 4, 5, 4)
    want = ref.conv1d_ref(x, wt, 4)
    got = conv1d_fwd(x, relayout_skc(wt), 4, block=block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_forward_bf16():
    x, wt, _, _ = _mk(2, 16, 16, 128, 5, 2, dtype=jnp.bfloat16)
    got = conv1d(x, wt, 2)
    want = ref.conv1d_ref(x.astype(jnp.float32), wt.astype(jnp.float32), 2)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), want, rtol=3e-2, atol=3e-2
    )


def test_forward_identity_filter():
    # S=1, single channel, unit weight: convolution is identity.
    x = jnp.arange(96, dtype=jnp.float32).reshape(1, 1, 96)
    wt = jnp.ones((1, 1, 1), jnp.float32)
    np.testing.assert_allclose(conv1d(x, wt, 3), x)


def test_forward_dilation_reach():
    # A 2-tap dilated filter [1, 1] with dilation d computes x[q] + x[q+d].
    d = 7
    x = jnp.arange(80, dtype=jnp.float32).reshape(1, 1, 80)
    wt = jnp.ones((1, 1, 2), jnp.float32)
    got = conv1d(x, wt, d)
    want = x[:, :, : 80 - d] + x[:, :, d:]
    np.testing.assert_allclose(got, want)


# ---------------------------------------------------------------- backward


@pytest.mark.parametrize("n,c,k,q,s,d", PAPER_GRID)
def test_bwd_data_matches_ref(n, c, k, q, s, d):
    w_in = q + (s - 1) * d
    x, wt, g, _ = _mk(n, c, k, w_in, s, d)
    got = conv1d_bwd_data(g, wt, d, w_in)
    want = ref.conv1d_bwd_data_ref(g, wt, d, w_in)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,c,k,q,s,d", PAPER_GRID)
def test_bwd_weight_matches_ref(n, c, k, q, s, d):
    w_in = q + (s - 1) * d
    x, wt, g, _ = _mk(n, c, k, w_in, s, d)
    got = conv1d_bwd_weight(g, x, d, s)
    want = ref.conv1d_bwd_weight_ref(g, x, d, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bwd_matches_vjp_jointly():
    x, wt, g, _ = _mk(2, 5, 6, 120, 9, 3, seed=7)
    gx_ref, gw_ref = ref.conv1d_vjp_ref(x, wt, g, 3)
    np.testing.assert_allclose(conv1d_bwd_data(g, wt, 3, 120), gx_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(conv1d_bwd_weight(g, x, 3, 9), gw_ref, rtol=1e-4, atol=1e-4)


def test_bwd_weight_accumulates_over_batch():
    # grad_w of a batch == sum of per-sample grad_w
    x, wt, g, _ = _mk(3, 4, 4, 100, 5, 2, seed=3)
    full = conv1d_bwd_weight(g, x, 2, 5)
    per = sum(
        conv1d_bwd_weight(g[i : i + 1], x[i : i + 1], 2, 5) for i in range(3)
    )
    np.testing.assert_allclose(full, per, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- hypothesis sweeps

shape_strategy = st.tuples(
    st.integers(1, 3),       # n
    st.integers(1, 12),      # c
    st.integers(1, 12),      # k
    st.integers(1, 150),     # q
    st.integers(1, 9),       # s
    st.integers(1, 8),       # d
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_forward_hypothesis(shape):
    n, c, k, q, s, d = shape
    w_in = q + (s - 1) * d
    x, wt, _, _ = _mk(n, c, k, w_in, s, d, seed=q * 31 + s)
    got = conv1d(x, wt, d)
    want = ref.conv1d_ref(x, wt, d)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(shape_strategy)
def test_backward_hypothesis(shape):
    n, c, k, q, s, d = shape
    w_in = q + (s - 1) * d
    x, wt, g, _ = _mk(n, c, k, w_in, s, d, seed=q * 17 + d)
    gx_ref, gw_ref = ref.conv1d_vjp_ref(x, wt, g, d)
    np.testing.assert_allclose(
        conv1d_bwd_data(g, wt, d, w_in), gx_ref, rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        conv1d_bwd_weight(g, x, d, s), gw_ref, rtol=2e-4, atol=2e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 2),
    st.sampled_from([2, 4, 8, 16]),
    st.sampled_from([2, 4, 8, 16]),
    st.integers(2, 60),
    st.sampled_from([1, 3, 5]),
    st.sampled_from([1, 2, 4]),
)
def test_forward_bf16_hypothesis(n, c, k, q, s, d):
    # Paper Sec. 4.3: BF16 path requires even channels/filters/width.
    q = q * 2
    w_in = q + (s - 1) * d
    x, wt, _, _ = _mk(n, c, k, w_in, s, d, dtype=jnp.bfloat16, seed=c * k + q)
    got = np.asarray(conv1d(x, wt, d), np.float32)
    want = ref.conv1d_ref(x.astype(jnp.float32), wt.astype(jnp.float32), d)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
