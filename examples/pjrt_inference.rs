//! AOT-artifact serving path: load the JAX-lowered HLO artifacts (L2/L1)
//! from `artifacts/`, compile them on the PJRT CPU client, and run
//! batched denoising + peak-calling inference from Rust — Python never
//! runs here.
//!
//! Run `make artifacts` first, then:
//! `cargo run --release --example pjrt_inference`

use dilconv1d::data::atacseq::TrackConfig;
use dilconv1d::data::make_batch;
use dilconv1d::metrics::auroc;
use dilconv1d::runtime::{Registry, Session, TrainState};

fn main() -> anyhow::Result<()> {
    let reg = match Registry::load("artifacts") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e:#}\n(run `make artifacts` first)");
            return Ok(());
        }
    };
    println!("artifact registry: {} entries", reg.artifacts.len());
    let mut sess = Session::cpu()?;
    println!("PJRT platform: {}", sess.platform());

    let variant = if reg.artifacts.contains_key("eval_step_atacworks") {
        "atacworks"
    } else {
        "tiny"
    };
    let mut st = TrainState::init(&reg, variant)?;
    println!(
        "model variant '{variant}': {} params, batch {}, width {}",
        st.params.len(),
        st.batch,
        st.width
    );
    sess.load(&st.eval_key(), &reg.get(&st.eval_key())?.path)?;
    sess.load(&st.train_key(), &reg.get(&st.train_key())?.path)?;

    // Generate a synthetic batch at the artifact's width.
    let mut track = TrackConfig::default().scaled(st.width);
    track.pad = 0;
    track.width = st.width;
    let idx: Vec<u64> = (0..st.batch as u64).collect();
    let b = make_batch(&track, 7, &idx);

    // A few training steps through the AOT train_step (loss must drop)...
    let mut first = None;
    for i in 0..5 {
        let l = st.step(&sess, &b.x, &b.clean, &b.peaks)?;
        println!("train step {i}: loss {:.5} (mse {:.5}, bce {:.5})", l.total, l.mse, l.bce);
        first.get_or_insert(l.total);
    }

    // ...then batched inference through the AOT eval_step.
    let t0 = std::time::Instant::now();
    let (denoised, probs) = st.eval(&sess, &b.x)?;
    let dt = t0.elapsed().as_secs_f64();
    let a = auroc::auroc(&probs, &b.peaks);
    println!(
        "eval: {} tracks x {} bases in {:.1} ms  ({:.1} tracks/s)",
        st.batch,
        st.width,
        dt * 1e3,
        st.batch as f64 / dt
    );
    println!(
        "denoised mean {:.3}, peak AUROC {}",
        denoised.iter().sum::<f32>() / denoised.len() as f32,
        a.map_or("n/a".into(), |v| format!("{v:.4}")),
    );
    println!("pjrt_inference OK");
    Ok(())
}
