//! END-TO-END DRIVER (DESIGN.md §8, T1/FIG7): train the full 25-layer
//! AtacWorks-like dilated-conv ResNet on synthetic ATAC-seq data with the
//! paper's BRGEMM kernels, logging the loss curve and validation AUROC
//! per epoch — the paper's Sec. 4.4 experiment at host scale.
//!
//! All layers compose here: synthetic data generation → prefetching
//! loader → sharded gradient computation through the Algorithm 2/3/4
//! kernels → ring all-reduce → Adam → AUROC evaluation.
//!
//! Run: `cargo run --release --example train_atacworks -- [epochs] [width] [precision]`
//! Defaults (epochs=6, width=1200, precision=f32) finish in a few
//! minutes on one core. `precision=bf16` exercises the paper's BF16
//! recipe: bf16 working weights + kernels, FP32 master weights and
//! gradient accumulation (split Adam). The recorded run lives in
//! EXPERIMENTS.md §T1.

use dilconv1d::config::TrainConfig;
use dilconv1d::coordinator::Trainer;
use dilconv1d::machine::Precision;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let width: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1_200);
    let precision = match args.get(3).map(String::as_str) {
        Some("bf16") => Precision::Bf16,
        _ => Precision::F32,
    };

    // The paper's architecture verbatim (25 conv layers, ch=15, S=51, d=8);
    // track width and dataset size scaled from 50 000/32 000 to host scale.
    let cfg = TrainConfig {
        channels: 15,
        n_blocks: 11,
        filter_size: 51,
        dilation: 8,
        segment_width: width,
        segment_pad: width / 10,
        train_segments: 32,
        batch_size: 4,
        epochs,
        lr: 2e-4,
        precision,
        ..TrainConfig::default()
    };
    println!(
        "== AtacWorks end-to-end training ==\n25 conv layers (ch={}, S={}, d={}), \
         track width {} (+{} pad), {} train segments, batch {}, {} epochs, {:?}",
        cfg.channels,
        cfg.filter_size,
        cfg.dilation,
        cfg.segment_width,
        cfg.segment_pad,
        cfg.train_segments,
        cfg.batch_size,
        cfg.epochs,
        cfg.precision
    );
    let mut trainer = Trainer::new(cfg).expect("trainer construction");
    println!(
        "parameters: {}  |  validation segments: {}\n",
        trainer.param_count(),
        trainer.dataset.validation.len()
    );
    println!("epoch |   loss    |   mse    |   bce    | val mse  | val AUROC | train s | eval s");
    println!("------|-----------|----------|----------|----------|-----------|---------|-------");
    let reports = trainer.train(|r| {
        println!(
            "{:>5} | {:>9.5} | {:>8.5} | {:>8.5} | {:>8.4} | {:>9} | {:>7.2} | {:>6.2}",
            r.epoch,
            r.train_loss,
            r.train_mse,
            r.train_bce,
            r.val_mse,
            r.val_auroc.map_or("n/a".into(), |a| format!("{a:.4}")),
            r.timing.train_secs,
            r.timing.eval_secs,
        );
    });
    let first = reports.first().expect("at least one epoch");
    let last = reports.last().unwrap();
    println!(
        "\nloss curve: {:.5} -> {:.5} ({} epochs, {} steps/epoch)",
        first.train_loss,
        last.train_loss,
        reports.len(),
        last.steps
    );
    println!(
        "final validation AUROC: {} (paper-scale runs reach ≈0.94 after 25 epochs on 32k segments)",
        last.val_auroc.map_or("n/a".into(), |a| format!("{a:.4}"))
    );
    assert!(
        last.train_loss < first.train_loss,
        "training must reduce the loss"
    );
    if let Some(a) = last.val_auroc {
        assert!(a > 0.5, "peak head must beat chance, got {a}");
    }
    println!("train_atacworks OK");
}
