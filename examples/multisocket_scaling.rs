//! Regenerates the paper's multi-socket scaling results (Figs. 8/9/10 and
//! Table 2): measured data-parallel training over in-process "sockets"
//! (real sharding + real ring all-reduce) at host scale, plus the machine
//! model's projection of the paper-scale workload onto 1–16 CPX/CLX
//! sockets and the 8×V100 comparison.
//!
//! Run: `cargo run --release --example multisocket_scaling`
//! Recorded output: EXPERIMENTS.md §FIG8–10/T2.

use dilconv1d::config::TrainConfig;
use dilconv1d::coordinator::{experiment, Trainer};
use dilconv1d::dist::{CommModel, Topology};
use dilconv1d::machine::workload::{model_epoch, Workload};
use dilconv1d::machine::{MachineSpec, Precision, Strategy};

fn main() {
    // ---- measured: real data-parallel replicas on this host ----
    // Each socket count runs twice: the monolithic post-backward
    // all-reduce and the bucketed, backward-overlapped one (DESIGN.md
    // §6). The two are bit-identical by construction (aligned ring);
    // "exposed" is the modeled part of the collective a backward pass
    // would not hide.
    println!("== measured: in-process data-parallel training (scaled workload) ==");
    println!("sockets | all-reduce        | steps | train s | loss      | comm(model) s | exposed s");
    let mut params_per_socket = Vec::new();
    for &sockets in &[1usize, 2, 4] {
        let mut params_mono: Option<Vec<f32>> = None;
        for overlap in [false, true] {
            let cfg = TrainConfig {
                channels: 8,
                n_blocks: 2,
                filter_size: 15,
                dilation: 4,
                segment_width: 600,
                segment_pad: 60,
                train_segments: 16,
                batch_size: 4,
                epochs: 1,
                sockets,
                overlap,
                bucket_mb: 0.005,
                ..TrainConfig::default()
            };
            let mut t = Trainer::new(cfg).expect("trainer");
            let r = t.run_epoch(0);
            println!(
                "{sockets:>7} | {:<17} | {:>5} | {:>7.2} | {:>9.5} | {:>13.4} | {:.4}",
                if overlap { "bucketed+overlap" } else { "monolithic" },
                r.steps,
                r.timing.train_secs,
                r.train_loss,
                r.modeled_comm_secs,
                r.exposed_comm_secs,
            );
            if overlap {
                assert_eq!(
                    params_mono.as_deref(),
                    Some(t.params()),
                    "overlapped all-reduce must be bit-identical to monolithic at {sockets} sockets"
                );
            } else {
                params_mono = Some(t.params().to_vec());
            }
        }
        params_per_socket.push(params_mono.expect("monolithic run recorded"));
    }
    // Data-parallel correctness: identical trajectories regardless of P.
    for (i, p) in params_per_socket.iter().enumerate().skip(1) {
        let max_dev = p
            .iter()
            .zip(&params_per_socket[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_dev < 1e-3,
            "socket count {} diverged from single-socket trajectory: {max_dev}",
            [1, 2, 4][i]
        );
    }
    println!("data-parallel trajectories identical across socket counts ✓\n");

    // ---- modeled: paper-scale epoch on CPX, Figs. 8/9 ----
    let w = Workload::paper();
    let comm = CommModel::fabric();
    for (label, prec) in [("Fig. 8 (FP32)", Precision::F32), ("Fig. 9 (BF16)", Precision::Bf16)] {
        println!("== {label}: modeled CPX epoch, paper workload ==");
        println!("sockets | batch | compute s | comm s | eval s | total s | speedup");
        let t1 = model_epoch(&w, &MachineSpec::cooper_lake(), prec, Strategy::Brgemm, &Topology::xeon(1), &comm);
        for &s in &[1usize, 2, 4, 8, 16] {
            let t = model_epoch(&w, &MachineSpec::cooper_lake(), prec, Strategy::Brgemm, &Topology::xeon(s), &comm);
            println!(
                "{s:>7} | {:>5} | {:>9.1} | {:>6.2} | {:>6.1} | {:>7.1} | {:>5.2}x",
                Topology::xeon(s).paper_batch_size(),
                t.compute_secs,
                t.comm_secs,
                t.eval_secs,
                t.total(),
                t1.total() / t.total(),
            );
        }
        println!();
    }

    // ---- Table 2 / Fig. 10: vs 8×V100 (162 s/epoch, AtacWorks paper) ----
    println!("== Table 2: modeled vs paper (8 V100 = 162 s/epoch) ==");
    println!("device   | prec | modeled s | modeled speedup | paper s | paper speedup");
    for (dev, spec, prec, sockets) in [
        ("16s CLX", MachineSpec::cascade_lake(), Precision::F32, 16usize),
        ("16s CPX", MachineSpec::cooper_lake(), Precision::F32, 16),
        ("8s CPX", MachineSpec::cooper_lake(), Precision::Bf16, 8),
        ("16s CPX", MachineSpec::cooper_lake(), Precision::Bf16, 16),
    ] {
        let t = model_epoch(&w, &spec, prec, Strategy::Brgemm, &Topology::xeon(sockets), &comm);
        let prec_s = if prec == Precision::F32 { "FP32" } else { "BF16" };
        let paper = experiment::TABLE2
            .iter()
            .find(|r| r.device == dev && r.precision == prec_s)
            .expect("paper row");
        println!(
            "{dev:<8} | {prec_s} | {:>9.1} | {:>14.2}x | {:>7.1} | {:>12.2}x",
            t.total(),
            162.0 / t.total(),
            paper.time_per_epoch,
            paper.speedup_vs_v100,
        );
    }
    println!("\nmultisocket_scaling OK");
}
