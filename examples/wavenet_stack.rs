//! Domain example from the paper's motivation (Sec. 1): a WaveNet-style
//! dilated convolution stack for audio, where the dilation doubles per
//! layer (1, 2, 4, …, 512) to cover a large receptive field at constant
//! cost — exactly the "generic across dilation parameters" case the
//! BRGEMM layer is built for (the sweep set d ∈ {1..16} in Sec. 4.3).
//!
//! Run: `cargo run --release --example wavenet_stack`

use dilconv1d::bench_harness::time_fn;
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Backend, Conv1dLayer, ConvParams};
use dilconv1d::machine::gflops;

fn main() {
    let (channels, s) = (16usize, 2usize); // WaveNet: 2-tap causal filters
    let layers = 10; // d = 1..512 -> receptive field 1024 samples
    let w = 16_384; // one audio chunk (~1 s at 16 kHz)
    let n = 1;

    // Build the stack.
    let stack: Vec<Conv1dLayer> = (0..layers)
        .map(|i| {
            let d = 1usize << i;
            let mut l = Conv1dLayer::new(channels, channels, s, d, rnd(channels * channels * s, i as u64));
            l.backend = Backend::Brgemm;
            l
        })
        .collect();
    let receptive: usize = stack.iter().map(|l| (l.s - 1) * l.d).sum::<usize>() + 1;
    println!(
        "WaveNet-style stack: {layers} layers, S={s}, d=1..{}, receptive field {receptive} samples",
        1 << (layers - 1)
    );

    // Forward the whole stack (same-padded so widths stay aligned).
    let x = rnd(n * channels * w, 99);
    let mut total_flops = 0u64;
    let t = time_fn(1, 3, || {
        let mut h = x.clone();
        for l in &stack {
            h = l.forward_same(&h, n, w);
        }
        std::hint::black_box(&h);
    });
    for l in &stack {
        let p = ConvParams::with_same_padding(n, l.c, l.k, w, l.s, l.d).unwrap();
        total_flops += p.flops();
    }
    println!(
        "stack forward: {:.2} ms ({:.2} GFLOP/s) for {} samples",
        t.median_secs * 1e3,
        gflops(total_flops, t.median_secs),
        w
    );

    // The paper's genericity claim: throughput is flat across dilations.
    println!("\nper-layer timing (efficiency must not degrade with d):");
    println!("{:>6} | {:>9} | {:>8}", "d", "median", "GF/s");
    let mut rates = Vec::new();
    for l in &stack {
        let p = ConvParams::with_same_padding(n, l.c, l.k, w, l.s, l.d).unwrap();
        let t = time_fn(1, 3, || {
            std::hint::black_box(l.forward_same(&x, n, w));
        });
        let r = gflops(p.flops(), t.median_secs);
        rates.push(r);
        println!("{:>6} | {:>7.2}ms | {:>8.2}", l.d, t.median_secs * 1e3, r);
    }
    let (min, max) = (
        rates.iter().cloned().fold(f64::INFINITY, f64::min),
        rates.iter().cloned().fold(0.0f64, f64::max),
    );
    println!(
        "\nthroughput spread across d=1..512: {:.2} (paper: generic kernels keep this near 1)",
        max / min
    );
    assert!(max / min < 4.0, "dilation genericity violated");
    println!("wavenet_stack OK");
}
