//! Regenerates the paper's efficiency figures (Figs. 4–6) at host scale:
//! for each (C, K, Q, S, d) grid point, measure the BRGEMM kernel and the
//! im2col library baseline, print host GFLOP/s + efficiency, and the
//! machine-model projection onto the paper's CLX/CPX sockets.
//!
//! Run: `cargo run --release --example efficiency_sweep -- [fig4|fig5|fig6]`
//! (A reduced grid by default; `dilconv sweep --figure fig4` runs the full
//! one. Recorded output: EXPERIMENTS.md §FIG4–6.)

use dilconv1d::bench_harness::{run_point, Pass, SweepConfig};
use dilconv1d::conv1d::Backend;
use dilconv1d::coordinator::experiment;
use dilconv1d::machine::{calibrate_host, MachineSpec, Precision};

fn main() {
    let fig = std::env::args().nth(1).unwrap_or_else(|| "fig4".into());
    let (grid, precision, machine) = match fig.as_str() {
        "fig4" => (experiment::fig4_grid(), Precision::F32, MachineSpec::cascade_lake()),
        "fig5" => (experiment::fig5_grid(), Precision::F32, MachineSpec::cascade_lake()),
        "fig6" => (experiment::fig6_grid(), Precision::Bf16, MachineSpec::cooper_lake()),
        other => panic!("unknown figure {other} (fig4|fig5|fig6)"),
    };
    // Reduced example grid: S ∈ {5, 51}, Q ≤ 20k (the full sweep is the
    // `dilconv sweep` subcommand).
    let grid: Vec<_> = grid
        .into_iter()
        .filter(|&(_, _, q, s, _)| (s == 5 || s == 51) && q <= 20_000)
        .collect();
    let host = calibrate_host();
    println!("{fig}: host sustained ≈ {host:.2} GFLOP/s\n");
    println!("  C   K      Q   S  d |   ours      GF/s   eff |  baseline  speedup | modeled eff (paper hw)");
    let cfg = SweepConfig {
        batch: 2,
        reps: 3,
        max_measured_q: 20_000,
        host_gflops_peak: host,
        threads: 1,
    };
    for (c, k, q, s, d) in grid {
        let ours = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Brgemm, precision, &machine);
        let base = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Im2col, Precision::F32, &machine);
        println!(
            "{c:>3} {k:>3} {q:>6} {s:>3} {d:>2} | {:>8.2}ms {:>7.2} {:>4.0}% | {:>8.2}ms  {:>5.2}x | ours {:>4.0}%  baseline {:>4.0}%",
            ours.timing.median_secs * 1e3,
            ours.host_gflops,
            ours.host_eff * 100.0,
            base.timing.median_secs * 1e3,
            base.timing.median_secs / ours.timing.median_secs,
            ours.modeled_eff * 100.0,
            base.modeled_eff * 100.0,
        );
    }
    println!("\nefficiency_sweep OK (paper shape: ours ≥ baseline whenever S≥5 ∧ Q≥1000 — eq. 4)");
}
