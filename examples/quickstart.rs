//! Quickstart: build a 1D dilated convolution layer at the paper's
//! AtacWorks shape (C=15, K=15, S=51, d=8), run forward + both backward
//! passes, check the three backends agree, and print achieved GFLOP/s —
//! then do it again through the plan/executor API (build a `ConvPlan`
//! once, execute into preallocated buffers with zero steady-state
//! allocations).
//!
//! Run: `cargo run --release --example quickstart`

use dilconv1d::bench_harness::time_fn;
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Backend, Conv1dLayer, ConvParams, ConvPlan};
use dilconv1d::machine::{gflops, Precision};

fn main() {
    // The paper's workhorse layer (Sec. 4.2): 15 channels, 15 filters,
    // filter width 51, dilation 8, on a 10 000-wide padded input.
    let (n, c, k, s, d, w) = (2, 15, 15, 51, 8, 10_000);
    let p = ConvParams::new(n, c, k, w, s, d).expect("valid conv problem");
    println!("problem: {p}  ({:.2} MFLOP/pass)", p.flops() as f64 / 1e6);

    let weights = rnd(k * c * s, 1);
    let x = rnd(n * c * w, 2);

    let mut layer = Conv1dLayer::new(c, k, s, d, weights);
    layer.backend = Backend::Brgemm;

    // Forward (paper Algorithm 2).
    let out = layer.forward(&x, n, w);
    println!("forward: out ({n}, {k}, {})", p.q());

    // Backends agree (BRGEMM vs im2col library-baseline vs direct oracle).
    for backend in [Backend::Im2col, Backend::Direct] {
        let mut alt = layer.clone();
        alt.backend = backend;
        let out2 = alt.forward(&x, n, w);
        let max_err = out
            .iter()
            .zip(&out2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("{backend} agrees with brgemm: max abs err {max_err:.2e}");
        assert!(max_err < 1e-3);
    }

    // Backward passes (Algorithms 3 and 4).
    let gout = rnd(n * k * p.q(), 3);
    let gin = layer.backward_data(&gout, n, w);
    let gw = layer.backward_weight(&gout, &x, n, w);
    println!("backward: grad_in {} elems, grad_w {} elems", gin.len(), gw.len());

    // Timings per backend (the Fig. 4 story in miniature).
    println!("\ntiming (median of 5):");
    for backend in Backend::ALL {
        let mut l = layer.clone();
        l.backend = backend;
        let t = time_fn(1, 5, || {
            std::hint::black_box(l.forward(&x, n, w));
        });
        println!(
            "  {backend}: {:8.2} ms  ({:6.2} GFLOP/s)",
            t.median_secs * 1e3,
            gflops(p.flops(), t.median_secs),
        );
    }

    // The plan/executor API: build once (layout derivation + workspace
    // sizing, the paper's "JIT at construction" phase), execute many
    // times with zero steady-state allocations.
    let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, layer.weights().to_vec())
        .expect("plan");
    println!(
        "\nplan: kernel '{}', workspace {} KiB",
        plan.kernel_name(),
        plan.workspace_bytes() / 1024
    );
    let mut out_planned = vec![0.0f32; n * k * p.q()];
    let t = time_fn(1, 5, || {
        plan.execute_forward_into(&x, &mut out_planned);
        std::hint::black_box(&out_planned);
    });
    println!(
        "  planned forward: {:8.2} ms  ({:6.2} GFLOP/s)",
        t.median_secs * 1e3,
        gflops(p.flops(), t.median_secs),
    );
    assert_eq!(out_planned, out, "planned path must be bit-exact");

    println!("\nquickstart OK");
}
